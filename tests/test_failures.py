"""Fault tolerance: pod failure/recovery, TPC-C shard failure, straggler math,
serving bookkeeping anti-entropy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models.sharding import Rules
from repro.optim import adamw, coord
from repro.runtime.failures import PodSimulator, straggler_step_times
from repro.runtime.serve import ServeConfig, Server, merge_server_bookkeeping

CFG = registry.get_config("smollm-360m").reduced()


def _single_pod_setup():
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    batch_specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                   for k, v in registry.make_train_batch(
                       jax.random.PRNGKey(0), CFG, 2, 16).items()}
    return coord.build(CFG, Rules(batch=("pod", "data")), mesh,
                       coord.CoordConfig(mode="sync"),
                       adamw.AdamWConfig(warmup_steps=1, total_steps=50),
                       lambda c, r: registry.make_loss_fn(c, r, remat=False),
                       batch_specs)


def test_pod_failure_and_recovery():
    """Survivors keep stepping through a failure; post-recovery merge
    converges and validity holds throughout (availability + convergence)."""
    sim = PodSimulator(_single_pod_setup(), n_pods=3)

    def batches(seed):
        return [registry.make_train_batch(jax.random.PRNGKey(seed + i),
                                          CFG, 2, 16) for i in range(3)]

    for t in range(2):
        sim.step(batches(t))
    sim.merge()
    assert sim.divergence() < 1e-5

    sim.kill(1)
    for t in range(2, 5):
        sim.step(batches(t))          # survivors make progress
        assert sim.check_validity()
    surviving_step = int(sim.states[0].step)
    assert surviving_step == 5

    sim.recover(1)                     # elastic restore from a survivor
    sim.step(batches(5))
    sim.merge()                        # anti-entropy reconciles
    assert sim.check_validity()
    assert sim.divergence() < 1e-5
    assert int(sim.states[1].step) >= surviving_step


def test_straggler_mitigation_model():
    """Transient stalls: sync pays every hiccup in the fleet; deferred merge
    absorbs them within the window (speedup grows with merge_every)."""
    out = straggler_step_times(n_pods=8, merge_every=16, steps=128,
                               slowdown=4.0, mode="transient")
    assert out["speedup"] > 1.2, out
    out1 = straggler_step_times(n_pods=8, merge_every=1, steps=128,
                                slowdown=4.0, mode="transient")
    assert out1["speedup"] == pytest.approx(1.0, abs=1e-6)
    assert out["speedup"] > out1["speedup"]
    # permanent straggler: no strategy helps (its own partition dominates)
    perm = straggler_step_times(n_pods=8, merge_every=16, steps=128,
                                slowdown=3.0, mode="permanent")
    assert perm["speedup"] < 1.1


def test_tpcc_shard_failure_recovery():
    """One warehouse shard pauses; others commit; recovery drains outboxes
    and the twelve criteria hold."""
    from repro.txn import tpcc
    from repro.txn.engine import single_host_engine
    from repro.txn.tpcc import TPCCScale, check_consistency, init_state

    scale = TPCCScale(n_warehouses=4, districts=2, customers=8, n_items=32,
                      order_capacity=64)
    eng = single_host_engine(scale)
    state = eng.shard_state(init_state(scale))
    rng = np.random.default_rng(0)

    pending = []
    # "shard 3 down": no transactions homed there commit, but others do
    for ts in range(4):
        batch = tpcc.generate_neworder(rng, scale, 12, remote_frac=0.3,
                                       w_lo=0, w_hi=3, ts0=ts * 12)
        state, outbox, _ = eng.neworder_step(state, batch)
        pending.append(outbox)

    # recovery: anti-entropy drains the queued remote updates (incl. those
    # destined to the recovered shard)
    for ob in pending:
        state = eng.anti_entropy(state, ob)
    c = check_consistency(state)
    assert all(c.values()), c
    # the recovered shard received its remote stock updates
    assert float(np.asarray(state.s_ytd)[3].sum()) > 0


def test_serving_escrow_and_gcounter_merge():
    params = registry.init_params(jax.random.PRNGKey(0), CFG)
    a = Server(CFG, params, ServeConfig(server_id=0, n_servers=2,
                                        admission_budget=100.0,
                                        max_new_tokens=2, capacity=32))
    b = Server(CFG, params, ServeConfig(server_id=1, n_servers=2,
                                        admission_budget=100.0,
                                        max_new_tokens=2, capacity=32))
    # replica-namespaced request ids never collide
    ids_a = [a.new_request_id() for _ in range(5)]
    ids_b = [b.new_request_id() for _ in range(5)]
    assert not set(ids_a) & set(ids_b)

    # escrow admission sheds load beyond the local share without coordination
    granted = 0
    for _ in range(20):
        if a.admit(np.zeros(8, np.int32)) is not None:
            granted += 1
    assert granted == 5  # share=50, cost=10 each
    a.served[0] += granted

    rep = merge_server_bookkeeping(a, b)
    assert rep["served_total"] == granted
    assert rep["escrow_remaining"] == pytest.approx(50.0)


def test_server_generates_tokens():
    params = registry.init_params(jax.random.PRNGKey(0), CFG)
    srv = Server(CFG, params, ServeConfig(max_new_tokens=3, capacity=32))
    reqs = [srv.admit(np.array([1, 2, 3], np.int32)),
            srv.admit(np.array([4, 5], np.int32))]
    assert all(r is not None for r in reqs)
    done = srv.serve_batch(reqs)
    assert all(r.done and len(r.generated) == 3 for r in done)
    assert srv.report()["served_total"] == 2


# ---------------------------------------------------------------------------
# Failure-tolerant escrow: kill -> reclaim -> drain -> recover (ISSUE 8)
# ---------------------------------------------------------------------------


def _escrow_scale():
    from repro.txn.tpcc import TPCCScale
    return TPCCScale(n_warehouses=4, districts=2, customers=8, n_items=32,
                     order_capacity=512, max_lines=15)


def test_escrow_kill_reclaim_drain_recover(tmp_path):
    """The closed loop: steady state -> checkpoint -> kill a replica ->
    survivors keep committing with the dead share row reclaimed to zero ->
    entries destined to the dead owner queue (nothing silently drops) ->
    recover from the manifest -> drain to quiescence -> the audit criteria
    (the twelve + the escrow laws) hold and the cold-tier ledger is EXACT:
    sent == applied + final_rejects."""
    from repro.runtime.failures import EscrowPodSimulator

    sim = EscrowPodSimulator(_escrow_scale(), n_replicas=4, retry_cap=64,
                             retry_max=3, seed=5)
    for _ in range(3):
        sim.step(8, remote_frac=0.5, item_skew=1.5)
        sim.drain()
        sim.refresh()
    sim.checkpoint(str(tmp_path), step=3)

    sim.kill(2)
    for _ in range(3):
        sim.step(8, remote_frac=0.5, item_skew=1.5)
        sim.drain()
        sim.refresh()
    led = sim.cold_ledger()
    assert led["exact"], led
    # share reclamation: the dead replica's row refreshed to ZERO and its
    # headroom partitions among the survivors (sum still covers budgets)
    assert int(np.asarray(sim.esc.shares[2]).sum()) == 0
    assert int(np.asarray(sim.esc.shares).sum()) > 0
    # the outage queued work at the dead owner instead of dropping it
    # (remote_frac=0.5 guarantees traffic toward replica 2's warehouses)
    assert len(sim.pending[2]) > 0

    sim.recover(2, str(tmp_path))
    for _ in range(sim.retry_max + 2):
        sim.drain()
    sim.refresh()
    led = sim.cold_ledger()
    assert led["exact"] and led["queued"] == 0 and led["in_ring"] == 0, led
    rep = sim.audit()
    assert rep.ok, rep.failures
    assert rep.checks["twelve_criteria"]
    assert rep.checks["escrow_covers_hot_stock"]


def test_escrow_recover_is_bit_identical_to_frozen_image(tmp_path):
    """Only the owner writes its slice, so the checkpointed image IS the
    dead replica's frozen state: recovery restores it bit-exactly."""
    from repro.runtime.failures import EscrowPodSimulator

    sim = EscrowPodSimulator(_escrow_scale(), n_replicas=2, retry_cap=32,
                             retry_max=2, seed=9)
    for _ in range(2):
        sim.step(8, remote_frac=0.4, item_skew=1.2)
        sim.drain()
        sim.refresh()
    sim.checkpoint(str(tmp_path), step=2)
    frozen = jax.tree.map(jnp.copy, sim.slices[1])
    sim.kill(1)
    for _ in range(2):
        sim.step(8, remote_frac=0.4, item_skew=1.2)
        sim.drain()
        sim.refresh()
    sim.recover(1, str(tmp_path))
    eq = jax.tree.map(lambda a, b: bool((a == b).all()), frozen,
                      sim.slices[1])
    assert all(eq), [f for f, ok in zip(frozen._fields, eq) if not ok]


def test_run_image_checkpoint_resume_through_run_loop(tmp_path):
    """Engine-level recovery: a run checkpointed mid-stream with
    ``final_flush=False`` (pending retry entries stay IN the ring, not
    flushed to rejects) restores bit-exactly and resumes through run_loop;
    a crash BETWEEN the shard write and the sequential-ID commit leaves
    latest_manifest returning the previous committed checkpoint."""
    from repro.txn import recovery, tpcc
    from repro.txn.drivers import run_loop
    from repro.txn.engine import single_host_engine

    scale = _escrow_scale()
    eng = single_host_engine(scale, stock_invariant="strict")
    state0 = eng.shard_state(tpcc.init_state(scale, seed=0))
    q0 = np.asarray(jax.device_get(state0.s_quantity)).copy()
    kw = dict(batch_per_shard=8, n_batches=8, remote_frac=0.6,
              merge_every=4, refresh_every=1, seed=3, item_skew=1.5)

    s, e, st, r = run_loop(eng, jax.tree.map(jnp.copy, state0),
                           retry_cap=64, retry_max=3, final_flush=False,
                           return_retry=True, **kw)
    man = recovery.save_run(str(tmp_path), s, 8, esc=e, retry=r)
    assert man.seq_id == 0

    rr = recovery.restore_run(str(tmp_path), eng)
    assert rr is not None and rr.step == 8
    eq = jax.tree.map(lambda a, b: bool((a == b).all()), s, rr.state)
    assert all(eq), [f for f, ok in zip(s._fields, eq) if not ok]
    for a, b in zip(jax.tree.leaves(r), jax.tree.leaves(rr.retry)):
        assert bool((a == b).all())

    # mid-commit crash: shard file + temp manifest written, commit skipped
    recovery.save_run(str(tmp_path), rr.state, 9, esc=rr.esc,
                      retry=rr.retry, commit=False)
    again = recovery.restore_run(str(tmp_path), eng)
    assert again.step == 8 and again.manifest.seq_id == 0

    # the restored image resumes and still audits clean
    s2, e2, st2, r2 = run_loop(eng, rr.state, rr.esc, retry_cap=64,
                               retry_max=3, retry=rr.retry,
                               return_retry=True, **kw)
    from repro.txn.audit import assert_audit
    assert_audit(s2, escrow=e2, initial_stock=q0, strict_stock=True)


def test_hot_path_collective_free_with_reclamation_and_retry():
    """The obs-ledger proof with the failure-tolerance features on: the
    liveness-masked refresh and the retry ring change NOTHING about the
    hot path's zero-collective budget, and the retry drain's collective
    traffic is identical to the non-retry drain (the ring is owner-local,
    never gathered)."""
    from repro.txn.engine import single_host_engine
    from repro.txn.executor import get_fused_executor

    eng = single_host_engine(_escrow_scale(), stock_invariant="strict")
    led = eng.coordination_ledger(chunk_len=4, batch_per_shard=8,
                                  payments=False, reads=False)
    assert led.snapshot()["hot_collectives"] == 0
    # refresh (now alive-masked) is still the amortized coordination point
    assert eng.count_refresh_collectives().total_ops > 0
    ex = get_fused_executor(eng, ring_rows=4, retry_cap=16)
    plain = ex.count_drain_strict_collectives(8)
    retry = ex.count_drain_strict_retry_collectives(8)
    assert dict(retry.counts) == dict(plain.counts)


def test_pod_metric_gcounter_survives_kill_and_recover():
    """Fleet metrics are a per-pod-slot G-counter: merge joins every live
    pod's contribution (slotwise max), a dead pod's last-merged slot stays
    in the fleet view, and a recovered pod resumes its OWN slot from the
    joined value — monotone, no loss, no double count."""
    sim = PodSimulator(_single_pod_setup(), n_pods=3)

    def batches(seed):
        return [registry.make_train_batch(jax.random.PRNGKey(seed + i),
                                          CFG, 2, 16) for i in range(3)]

    sim.step(batches(0))
    sim.merge()
    before = sim.fleet_metrics()
    assert before["tokens"] > 0

    sim.kill(1)
    killed_slot = sim.metric_joined["tokens"][1]
    assert killed_slot > 0          # pod 1's pre-kill merge is retained
    sim.step(batches(1))
    mid = sim.fleet_metrics()
    # monotone: the survivors grow the fleet view, pod 1's slot is frozen
    assert mid["tokens"] > before["tokens"]
    assert sim.metric_joined["tokens"][1] == killed_slot

    sim.recover(1)
    # the recovered pod resumed from its joined slot, NOT the survivor's
    # (inheriting the survivor's slots would double-count at the next join)
    assert float(sim.states[1].token_slots.sum()) == pytest.approx(
        killed_slot)
    sim.step(batches(2))
    sim.merge()
    after = sim.fleet_metrics()
    assert after["tokens"] > mid["tokens"]
    # exact: fleet tokens == sum of per-slot maxima, each counted once
    assert after["tokens"] == pytest.approx(
        float(sim.metric_joined["tokens"].sum()))


_RECLAIM_SUBPROC = r"""
import tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.txn.engine import single_host_engine
from repro.txn.drivers import run_loop
from repro.txn import tpcc, recovery
from repro.txn.audit import assert_audit
assert len(jax.devices()) == 4, jax.devices()

scale = tpcc.TPCCScale(n_warehouses=4, districts=2, customers=8, n_items=32,
                       order_capacity=512, max_lines=15)
eng = single_host_engine(scale, stock_invariant="strict")
state0 = eng.shard_state(tpcc.init_state(scale, seed=0))
q0 = state0.s_quantity.copy()
kw = dict(batch_per_shard=8, n_batches=16, remote_frac=0.6, merge_every=4,
          refresh_every=1, seed=3, item_skew=1.5)

# baseline vs retry_max=0: the ring must be a bitwise no-op
s_b, e_b, st_b = run_loop(eng, jax.tree.map(jnp.copy, state0), **kw)
s_0, e_0, st_0, _ = run_loop(eng, jax.tree.map(jnp.copy, state0),
                             retry_cap=256, retry_max=0,
                             return_retry=True, **kw)
eq = jax.tree.map(lambda a, b: bool((a == b).all()), s_b, s_0)
assert all(eq), [f for f, ok in zip(s_b._fields, eq) if not ok]
assert st_0.cold_rejects == st_b.cold_rejects
print("BITEXACT-R0 OK")

# retry actually recovers rejects; fused == dispatch under the greedy pass
s_r, e_r, st_r, r_r = run_loop(eng, jax.tree.map(jnp.copy, state0),
                               retry_cap=256, retry_max=3,
                               return_retry=True, **kw)
assert st_r.cold_rejects < st_b.cold_rejects, (st_r.cold_rejects,
                                               st_b.cold_rejects)
s_d, e_d, st_d, r_d = run_loop(eng, jax.tree.map(jnp.copy, state0),
                               retry_cap=256, retry_max=3, fused=False,
                               return_retry=True, **kw)
eq = jax.tree.map(lambda a, b: bool((a == b).all()), s_r, s_d)
assert all(eq), [f for f, ok in zip(s_r._fields, eq) if not ok]
assert st_d.cold_rejects == st_r.cold_rejects
assert_audit(s_r, escrow=e_r, initial_stock=q0, strict_stock=True)
print("RETRY-PARITY OK")

# reclamation on real shards: one dead slot refreshes to zero, the
# partition still covers the hot stock exactly
alive = jnp.asarray([1, 1, 0, 1], jnp.int32)
s_a, e_a, st_a, _ = run_loop(eng, jax.tree.map(jnp.copy, state0),
                             retry_cap=256, retry_max=3, alive=alive,
                             return_retry=True, **kw)
shares = np.asarray(jax.device_get(e_a.shares))
assert shares[2].sum() == 0, "dead slot must hold zero shares"
hot_q = np.asarray(jax.device_get(s_a.s_quantity)).reshape(-1)[
    np.asarray(jax.device_get(e_a.keys))]
spent = np.asarray(jax.device_get(e_a.spent))
assert np.array_equal(shares.sum(0) - spent.sum(0), hot_q)
print("RECLAIM OK")

# checkpoint mid-run image, restore under the 4-shard mesh, resume
import os
d = tempfile.mkdtemp()
s_c, e_c, st_c, r_c = run_loop(eng, jax.tree.map(jnp.copy, state0),
                               retry_cap=64, retry_max=3,
                               final_flush=False, return_retry=True, **kw)
recovery.save_run(d, s_c, 16, esc=e_c, retry=r_c)
rr = recovery.restore_run(d, eng)
eq = jax.tree.map(lambda a, b: bool((a == b).all()), s_c, rr.state)
assert all(eq)
s_f, e_f, st_f, _ = run_loop(eng, rr.state, rr.esc, retry_cap=64,
                             retry_max=3, retry=rr.retry,
                             return_retry=True, **kw)
assert_audit(s_f, escrow=e_f, initial_stock=q0, strict_stock=True)
print("RESUME OK")
"""


@pytest.mark.slow
def test_multi_device_reclaim_retry_subprocess():
    """4 simulated devices: the retry ring is bit-exact off, recovers
    rejects on, fused == dispatch under greedy admission, a dead shard's
    share slot reclaims to zero with the partition still covering hot
    stock, and a checkpointed run image resumes under the sharded mesh.

    Runs in a subprocess so the main test process keeps 1 CPU device."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _RECLAIM_SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for marker in ("BITEXACT-R0 OK", "RETRY-PARITY OK", "RECLAIM OK",
                   "RESUME OK"):
        assert marker in out.stdout, out.stdout
