"""Fault tolerance: pod failure/recovery, TPC-C shard failure, straggler math,
serving bookkeeping anti-entropy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models.sharding import Rules
from repro.optim import adamw, coord
from repro.runtime.failures import PodSimulator, straggler_step_times
from repro.runtime.serve import ServeConfig, Server, merge_server_bookkeeping

CFG = registry.get_config("smollm-360m").reduced()


def _single_pod_setup():
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    batch_specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                   for k, v in registry.make_train_batch(
                       jax.random.PRNGKey(0), CFG, 2, 16).items()}
    return coord.build(CFG, Rules(batch=("pod", "data")), mesh,
                       coord.CoordConfig(mode="sync"),
                       adamw.AdamWConfig(warmup_steps=1, total_steps=50),
                       lambda c, r: registry.make_loss_fn(c, r, remat=False),
                       batch_specs)


def test_pod_failure_and_recovery():
    """Survivors keep stepping through a failure; post-recovery merge
    converges and validity holds throughout (availability + convergence)."""
    sim = PodSimulator(_single_pod_setup(), n_pods=3)

    def batches(seed):
        return [registry.make_train_batch(jax.random.PRNGKey(seed + i),
                                          CFG, 2, 16) for i in range(3)]

    for t in range(2):
        sim.step(batches(t))
    sim.merge()
    assert sim.divergence() < 1e-5

    sim.kill(1)
    for t in range(2, 5):
        sim.step(batches(t))          # survivors make progress
        assert sim.check_validity()
    surviving_step = int(sim.states[0].step)
    assert surviving_step == 5

    sim.recover(1)                     # elastic restore from a survivor
    sim.step(batches(5))
    sim.merge()                        # anti-entropy reconciles
    assert sim.check_validity()
    assert sim.divergence() < 1e-5
    assert int(sim.states[1].step) >= surviving_step


def test_straggler_mitigation_model():
    """Transient stalls: sync pays every hiccup in the fleet; deferred merge
    absorbs them within the window (speedup grows with merge_every)."""
    out = straggler_step_times(n_pods=8, merge_every=16, steps=128,
                               slowdown=4.0, mode="transient")
    assert out["speedup"] > 1.2, out
    out1 = straggler_step_times(n_pods=8, merge_every=1, steps=128,
                                slowdown=4.0, mode="transient")
    assert out1["speedup"] == pytest.approx(1.0, abs=1e-6)
    assert out["speedup"] > out1["speedup"]
    # permanent straggler: no strategy helps (its own partition dominates)
    perm = straggler_step_times(n_pods=8, merge_every=16, steps=128,
                                slowdown=3.0, mode="permanent")
    assert perm["speedup"] < 1.1


def test_tpcc_shard_failure_recovery():
    """One warehouse shard pauses; others commit; recovery drains outboxes
    and the twelve criteria hold."""
    from repro.txn import tpcc
    from repro.txn.engine import single_host_engine
    from repro.txn.tpcc import TPCCScale, check_consistency, init_state

    scale = TPCCScale(n_warehouses=4, districts=2, customers=8, n_items=32,
                      order_capacity=64)
    eng = single_host_engine(scale)
    state = eng.shard_state(init_state(scale))
    rng = np.random.default_rng(0)

    pending = []
    # "shard 3 down": no transactions homed there commit, but others do
    for ts in range(4):
        batch = tpcc.generate_neworder(rng, scale, 12, remote_frac=0.3,
                                       w_lo=0, w_hi=3, ts0=ts * 12)
        state, outbox, _ = eng.neworder_step(state, batch)
        pending.append(outbox)

    # recovery: anti-entropy drains the queued remote updates (incl. those
    # destined to the recovered shard)
    for ob in pending:
        state = eng.anti_entropy(state, ob)
    c = check_consistency(state)
    assert all(c.values()), c
    # the recovered shard received its remote stock updates
    assert float(np.asarray(state.s_ytd)[3].sum()) > 0


def test_serving_escrow_and_gcounter_merge():
    params = registry.init_params(jax.random.PRNGKey(0), CFG)
    a = Server(CFG, params, ServeConfig(server_id=0, n_servers=2,
                                        admission_budget=100.0,
                                        max_new_tokens=2, capacity=32))
    b = Server(CFG, params, ServeConfig(server_id=1, n_servers=2,
                                        admission_budget=100.0,
                                        max_new_tokens=2, capacity=32))
    # replica-namespaced request ids never collide
    ids_a = [a.new_request_id() for _ in range(5)]
    ids_b = [b.new_request_id() for _ in range(5)]
    assert not set(ids_a) & set(ids_b)

    # escrow admission sheds load beyond the local share without coordination
    granted = 0
    for _ in range(20):
        if a.admit(np.zeros(8, np.int32)) is not None:
            granted += 1
    assert granted == 5  # share=50, cost=10 each
    a.served[0] += granted

    rep = merge_server_bookkeeping(a, b)
    assert rep["served_total"] == granted
    assert rep["escrow_remaining"] == pytest.approx(50.0)


def test_server_generates_tokens():
    params = registry.init_params(jax.random.PRNGKey(0), CFG)
    srv = Server(CFG, params, ServeConfig(max_new_tokens=3, capacity=32))
    reqs = [srv.admit(np.array([1, 2, 3], np.int32)),
            srv.admit(np.array([4, 5], np.int32))]
    assert all(r is not None for r in reqs)
    done = srv.serve_batch(reqs)
    assert all(r.done and len(r.generated) == 3 for r in done)
    assert srv.report()["served_total"] == 2
