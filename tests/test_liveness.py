"""Self-detecting liveness: lease lattice laws, the monitor's detection /
hysteresis algebra, the no-caller-mask chaos loop (kill -> detect -> reclaim
-> degraded serving -> revive -> handback), and the cold-line reservation
round-trip that bounds tail starvation.

Deterministic tests always run; hypothesis sweeps (revive-never-oversells,
reservation-rescues-starved-line) run where hypothesis is installed — CI
installs it via the ``test`` extra.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: deterministic only
    HAVE_HYPOTHESIS = False

from repro.core.lattice import (LeaseLattice, check_lattice_laws, get_bottom,
                                get_join, pack_lease_stamp,
                                unpack_lease_stamp)
from repro.runtime.failures import EscrowPodSimulator, PodSimulator
from repro.runtime.liveness import LeaseMonitor
from repro.txn import tpcc
from repro.txn.audit import check_cold_ledger


def _scale():
    return tpcc.TPCCScale(n_warehouses=4, districts=2, customers=8,
                          n_items=32, order_capacity=1024, max_lines=15)


def _chaos_sim(**kw):
    defaults = dict(retry_cap=128, retry_max=3, seed=11, stock_scale=20,
                    liveness=True)
    defaults.update(kw)
    return EscrowPodSimulator(_scale(), 4, **defaults)


def _window(sim, batch=12):
    sim.step(batch)
    sim.drain()
    sim.refresh()


# ---------------------------------------------------------------------------
# Lease lattice: registration, laws, stamp packing
# ---------------------------------------------------------------------------


def test_lease_lattice_registered_and_lawful():
    """The lease lattice registers like every other CRDT in the repo and its
    join is commutative/associative/idempotent over adversarial samples
    (incl. epoch-bump dominance and high-seq stamps past 32 bits)."""
    assert get_join("lease") is LeaseLattice.join
    bottom = get_bottom("lease")(3)
    assert np.array_equal(np.asarray(bottom.stamps), np.zeros(3))
    samples = [
        bottom,
        bottom.beat(0, 0, 5),
        bottom.beat(1, 2, 1),                       # epoch 2 dominates
        bottom.beat(0, 1, 0).beat(2, 0, (1 << 33)),  # seq wraps into mask
        LeaseLattice(np.asarray([7, 0, 1 << 40], np.int64)),
    ]
    check_lattice_laws(LeaseLattice.join, samples)


def test_lease_stamp_pack_monotone_across_epochs():
    """Packed stamps order first by epoch, then by seq — a revived replica
    (epoch bump, seq reset) stays strictly above its old incarnation, so
    the fleet MaxReg never moves backwards through a rejoin."""
    assert int(pack_lease_stamp(0, 5)) < int(pack_lease_stamp(0, 6))
    assert int(pack_lease_stamp(0, (1 << 32) - 1)) < int(pack_lease_stamp(1, 0))
    e, s = unpack_lease_stamp(pack_lease_stamp(3, 41))
    assert (int(e), int(s)) == (3, 41)
    lat = LeaseLattice.make(2).beat(0, 0, 100)
    lat2 = lat.beat(0, 1, 0)    # rejoin: epoch 1, seq restarts
    assert int(lat2.stamps[0]) > int(lat.stamps[0])
    # a stale duplicate of the old incarnation joins in as a no-op
    joined = LeaseLattice.join(lat2, lat)
    assert np.array_equal(joined.stamps, lat2.stamps)


# ---------------------------------------------------------------------------
# LeaseMonitor: detection bound, hysteresis, revival
# ---------------------------------------------------------------------------


def test_monitor_detects_within_bound_and_revives():
    mon = LeaseMonitor(3, expiry=1, hysteresis=1)
    seq = [0, 0, 0]

    def beat_all(except_for=()):
        for r in range(3):
            if r not in except_for:
                seq[r] += 1
                mon.beat(r, 0, seq[r])

    for _ in range(3):
        beat_all()
        assert mon.tick().all()
    # replica 1 goes silent: must be declared dead within detection_bound
    died_at = mon.window
    while mon.window < died_at + mon.detection_bound:
        beat_all(except_for=(1,))
        alive = mon.tick()
    assert not alive[1] and alive[0] and alive[2]
    assert mon.detection_lags() == [mon.detection_bound]
    # silence continues: no duplicate detection events
    beat_all(except_for=(1,))
    mon.tick()
    assert len(mon.detections) == 1
    # replica 1 beats again (false suspicion): revived automatically
    beat_all()
    assert mon.tick().all()
    assert mon.revivals and mon.revivals[-1][1] == 1


def test_monitor_straggler_survives_hysteresis():
    """A replica silent for <= expiry + hysteresis windows is never
    declared dead — one slow chunk costs nothing."""
    mon = LeaseMonitor(2, expiry=1, hysteresis=1)
    seq = 0
    for w in range(12):
        seq += 1
        mon.beat(0, 0, seq)
        # replica 1 beats only every other window (always one stall long,
        # inside the hysteresis allowance)
        if w % 2 == 0:
            mon.beat(1, 0, w + 1)
        assert mon.tick().all()
    assert mon.detections == []


def test_monitor_source_polled_each_tick():
    stamps = np.zeros(2, np.int64)
    mon = LeaseMonitor(2, source=lambda w: stamps)
    stamps[:] = [int(pack_lease_stamp(0, 1))] * 2
    assert mon.tick().all()
    # only replica 0 advances from here on
    for w in range(2, 2 + mon.detection_bound):
        stamps[0] = int(pack_lease_stamp(0, w))
        alive = mon.tick()
    assert alive[0] and not alive[1]


# ---------------------------------------------------------------------------
# PodSimulator dataclass hygiene (the default_factory fix)
# ---------------------------------------------------------------------------


def test_pod_simulator_fields_never_alias():
    """Two simulators must not share mutable field storage, and
    caller-provided states/alive must survive __post_init__ (the
    ``list = None`` + unconditional-overwrite footgun this guards)."""
    class _Setup:
        init_fn = staticmethod(lambda key: {"p": np.zeros(2)})
        step_fn = staticmethod(lambda s, b: s)

    a = PodSimulator(_Setup(), n_pods=2)
    b = PodSimulator(_Setup(), n_pods=2)
    assert a.states is not b.states and a.alive is not b.alive
    assert a.metric_joined is not b.metric_joined
    assert a.metric_joined["loss"] is not b.metric_joined["loss"]
    a.kill(0)
    assert b.alive == [True, True]
    # caller-provided fleet image is kept, not clobbered
    provided = [{"p": np.ones(2)}, {"p": np.ones(2)}]
    c = PodSimulator(_Setup(), n_pods=2, states=provided, alive=[True, False])
    assert c.states is provided and c.alive == [True, False]


# ---------------------------------------------------------------------------
# Chaos matrix: the closed loop with NO caller-provided mask
# ---------------------------------------------------------------------------


def _quiesce_and_check(sim):
    sim.quiesce()
    led = sim.cold_ledger()
    check_cold_ledger(led, quiescent=True)
    sim.refresh()           # reconcile shares with post-drain stock
    sim.audit()
    return led


def test_chaos_single_kill_detect_reclaim_degraded_continue():
    """kill -> (lease detects) -> reclaim + successor adoption -> survivors
    keep serving AND the dead shard's cold traffic keeps draining — nobody
    ever hands the simulator an alive mask."""
    sim = _chaos_sim()
    for _ in range(3):
        _window(sim)
    committed_before = sim.committed
    sim.kill(1)
    windows_to_detect = 0
    while sim.alive[1]:
        _window(sim)
        windows_to_detect += 1
        assert windows_to_detect <= sim.monitor.detection_bound, \
            "detection exceeded the lease bound"
    # detection recorded, at the bound for a hard kill
    assert sim.monitor.detection_lags() == [sim.monitor.detection_bound]
    # successor adoption: shard 1 re-keyed to a live replica in ring order
    assert sim.owner_of[1] == 2
    queued_at_dead = sum(1 for _ in sim.pending[1])
    for _ in range(3):
        _window(sim)
    # degraded-mode elastic continue: fleet still commits, and the dead
    # shard's queue is NOT frozen (the successor drains it)
    assert sim.committed > committed_before
    assert len(sim.pending[1]) == 0 or queued_at_dead == 0
    led = _quiesce_and_check(sim)
    assert led["queued"] == 0, "dead shard's cold traffic starved"


def test_chaos_kill_then_revive_hands_shard_back():
    sim = _chaos_sim()
    for _ in range(2):
        _window(sim)
    sim.kill(3)
    for _ in range(sim.monitor.detection_bound + 1):
        _window(sim)
    assert not sim.alive[3] and sim.owner_of[3] == 0  # ring wraps 3 -> 0
    sim.revive(3)
    for _ in range(2):
        _window(sim)
    # the beat (under a bumped epoch) re-admits the replica; ownership
    # hands back deterministically
    assert sim.alive[3] and sim.owner_of[3] == 3
    assert sim.epoch[3] == 1
    _quiesce_and_check(sim)


def test_chaos_false_suspicion_self_fences_then_recovers():
    """A straggler stalled past the lease bound is falsely declared dead;
    it self-fences (stops serving) while suspected, its shard is adopted,
    and its next beat revives it — min-join share safety means the window
    of suspicion can waste throughput but never oversell (the audit's
    never-oversell law holds through the whole episode)."""
    sim = _chaos_sim()
    for _ in range(2):
        _window(sim)
    long_stall = sim.monitor.detection_bound + 2
    sim.stall(0, long_stall)
    saw_suspected = False
    for _ in range(long_stall + 2):
        _window(sim)
        if not sim.alive[0]:
            saw_suspected = True
            assert sim.owner_of[0] == 1      # adopted while suspected
    assert saw_suspected, "stall past the bound must trigger suspicion"
    for _ in range(2):
        _window(sim)
    # the stall ended; beats resumed; the fleet re-admitted it
    assert sim.alive[0] and sim.owner_of[0] == 0
    assert sim.monitor.revivals
    _quiesce_and_check(sim)


def test_chaos_straggler_within_hysteresis_not_suspected():
    sim = _chaos_sim()
    for _ in range(2):
        _window(sim)
    sim.stall(2, sim.lease_expiry + sim.lease_hysteresis)  # inside allowance
    for _ in range(6):
        _window(sim)
        assert sim.alive[2], "straggler inside hysteresis must survive"
    assert sim.monitor.detections == []
    _quiesce_and_check(sim)


def test_chaos_cascading_kills_last_survivor_serves_all():
    sim = _chaos_sim()
    for _ in range(2):
        _window(sim)
    sim.kill(0)
    for _ in range(sim.monitor.detection_bound):
        _window(sim)
    sim.kill(1)
    sim.kill(3)
    for _ in range(sim.monitor.detection_bound + 1):
        _window(sim)
    assert sim.alive == [False, False, True, False]
    assert sim.owner_of == [2, 2, 2, 2]      # one survivor owns everything
    for _ in range(2):
        _window(sim)
    led = _quiesce_and_check(sim)
    assert led["queued"] == 0


def test_liveness_off_is_legacy_bit_identical():
    """liveness=False keeps the omniscient-caller semantics bit-exactly:
    same seeds, same kills, same final state and ledger as before the
    lease layer existed (the PR-7 tests' world)."""
    def run(liveness):
        sim = EscrowPodSimulator(_scale(), 4, retry_cap=64, retry_max=2,
                                 seed=5, stock_scale=10, liveness=liveness)
        for _ in range(4):
            _window(sim, batch=8)
        return sim
    legacy = run(False)
    lease = run(True)
    # no kills: identical traffic, identical state
    for a, b in zip(jax.tree.leaves(legacy.full_state()),
                    jax.tree.leaves(lease.full_state())):
        assert bool((a == b).all())
    assert legacy.cold_ledger() == lease.cold_ledger()


# ---------------------------------------------------------------------------
# Reservations: the round-trip that bounds tail starvation
# ---------------------------------------------------------------------------

_RES_SCALE = tpcc.TPCCScale(1, 2, 16, 64, 1024, 15)
_HOT0 = jnp.asarray([0], jnp.int32)   # cell (0, 0) hot; everything else cold


def _res_window(st, ring, entries, reserve, retry_max=3):
    """One drain window over explicit (w, i, qty) cold entries."""
    n = max(len(entries), 1)
    dst = np.zeros(n, np.int32)
    iid = np.zeros(n, np.int32)
    qty = np.zeros(n, np.int32)
    mask = np.zeros(n, bool)
    for j, (w, i, q) in enumerate(entries):
        dst[j], iid[j], qty[j], mask[j] = w, i, q, True
    return tpcc.apply_stock_updates_strict_tiered_retry(
        st, _HOT0, jnp.asarray(dst), jnp.asarray(iid), jnp.asarray(qty),
        jnp.asarray(mask), jnp.ones(n, jnp.bool_), ring,
        _RES_SCALE.n_items, retry_max=retry_max, reserve=reserve)


def _local_sale(st, cell_i, qty):
    """The owner's hot path consuming local cold stock between drains
    (FCFS: admits iff it fits) — the traffic reservations protect against."""
    have = int(st.s_quantity[0, cell_i])
    if qty > have:
        return st
    return st._replace(
        s_quantity=st.s_quantity.at[0, cell_i].add(-qty),
        s_ytd=st.s_ytd.at[0, cell_i].add(float(qty)))


def _starved_line_outcome(reserve, *, stock, blocker, victim, local_sale,
                          cell=5):
    """Drive the head-of-line starvation schedule; returns (victim_applied,
    finals, end_stock).  Schedule: an OLD blocker enters the ring first
    (greedy-by-age sorts it ahead forever), the victim arrives a window
    later (rejected at arrival by all-or-nothing alongside a helper
    blocker), then the owner's local traffic consumes stock between the
    victim's last-chance window and its final window."""
    st = tpcc.init_state(_RES_SCALE, seed=0)
    st = st._replace(s_quantity=st.s_quantity.at[0, cell].set(stock),
                     s_ytd=st.s_ytd.at[0, cell].set(0.0))
    sold0 = float(st.s_ytd[0, cell])
    ring = tpcc.empty_retry(8)
    finals = 0
    # w0: old blocker alone -> rejected into the ring
    st, ring, f = _res_window(st, ring, [(0, cell, blocker)], reserve)
    finals += int(f)
    # w1: victim + helper together (window total can't fit) -> both ring
    st, ring, f = _res_window(st, ring, [(0, cell, victim),
                                         (0, cell, blocker)], reserve)
    finals += int(f)
    # w2: all three re-present; every prefix poisoned by the old blocker
    st, ring, f = _res_window(st, ring, [], reserve)
    finals += int(f)
    # w3: the victim's LAST-CHANCE window (old blocker finals here and
    # still poisons pass-1; with reserve on, pass 3 grants the victim)
    st, ring, f = _res_window(st, ring, [], reserve)
    finals += int(f)
    # between windows: the owner's local hot path consumes the cell
    before_sale = float(st.s_ytd[0, cell])
    st = _local_sale(st, cell, local_sale)
    sold_locally = float(st.s_ytd[0, cell]) - before_sale
    # w4: victim's final window (reserve off) / completion window (on)
    st, ring, f = _res_window(st, ring, [], reserve)
    finals += int(f)
    for _ in range(3):      # drain the helper out
        st, ring, f = _res_window(st, ring, [], reserve)
        finals += int(f)
    assert int(np.asarray(ring.valid).sum()) == 0
    victim_applied = (float(st.s_ytd[0, cell]) - sold0) - sold_locally
    return victim_applied, finals, int(st.s_quantity[0, cell])


def test_reservation_rescues_starved_line():
    """The property reservations exist for: greedy-by-age ALONE
    final-rejects a small line the reservation path admits.  The victim is
    head-of-line blocked through every retry (an older blocker poisons its
    pass-1 prefix), and by its final window the owner's local traffic has
    consumed the stock that covered it — the reservation's grant-now
    semantics claims the stock one window earlier, while it still fits."""
    kw = dict(stock=10, blocker=100, victim=8, local_sale=3)
    v0, finals0, stock0 = _starved_line_outcome(0, **kw)
    v1, finals1, stock1 = _starved_line_outcome(1, **kw)
    # greedy-by-age alone: victim starves (3 finals: 2 blockers + victim)
    assert v0 == 0.0 and finals0 == 3
    # reservations: victim applied at grant, only the blockers final
    assert v1 >= 8.0 and finals1 == 2
    assert stock1 == stock0 - 8 + 3   # grant debited; local sale fenced out


def test_reserve_zero_is_bit_identical_and_never_reserves():
    """reserve=0 must be the pre-reservation drain bit-exactly: identical
    state/ring/finals, and the reserved lane never sets."""
    st = tpcc.init_state(_RES_SCALE, seed=1)
    st = st._replace(s_quantity=st.s_quantity.at[0, 5].set(7))
    rng = np.random.default_rng(0)
    sa = sb = st
    ra = rb = tpcc.empty_retry(8)
    for w in range(6):
        entries = [(0, 5, int(rng.integers(1, 9))) for _ in range(3)]
        sa, ra, fa = _res_window(sa, ra, entries, reserve=0)
        sb, rb, fb = _res_window(sb, rb, entries, reserve=jnp.asarray(0))
        assert int(fa) == int(fb)
        assert not bool(np.asarray(ra.reserved).any())
        for x, y in zip(jax.tree.leaves((sa, ra)), jax.tree.leaves((sb, rb))):
            assert bool((x == y).all())


def test_reservation_never_oversells_and_ledger_exact():
    """Simulator-level: reservations under real chaos keep stock
    nonnegative at every window (the grant IS the admission), the extended
    ledger identity res_granted == res_completed + reserved_in_ring holds
    continuously, and quiescence closes both ledgers exactly."""
    sim = _chaos_sim(reserve=True, stock_scale=2, seed=3)
    sim.kill(2)
    for w in range(8):
        _window(sim, batch=16)
        assert bool((sim.full_state().s_quantity >= 0).all())
        led = sim.cold_ledger()
        assert led["exact"] and led["reservations_exact"], led
    sim.revive(2)
    for w in range(3):
        _window(sim, batch=16)
    led = _quiesce_and_check(sim)
    assert led["res_granted"] == led["res_completed"]


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(stock=st.integers(5, 40), victim=st.integers(2, 10),
           sale_frac=st.floats(0.2, 0.95))
    def test_reservation_rescue_property(stock, victim, sale_frac):
        """Across the starvation regime (victim fits stock; the local sale
        leaves less than the victim needs), greedy-by-age alone ALWAYS
        final-rejects the victim and reservations ALWAYS admit it."""
        if victim > stock:
            victim = stock
        local_sale = int(sale_frac * stock)
        if stock - local_sale >= victim:      # keep inside the regime
            local_sale = stock - victim + 1
        kw = dict(stock=stock, blocker=10 * stock, victim=victim,
                  local_sale=local_sale)
        v0, f0, _ = _starved_line_outcome(0, **kw)
        v1, f1, _ = _starved_line_outcome(1, **kw)
        assert v0 == 0.0 and f0 == 3
        assert v1 >= float(victim) and f1 == 2

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2 ** 16),
           kills=st.lists(st.tuples(st.integers(0, 3), st.integers(1, 4),
                                    st.integers(1, 5)),
                          min_size=1, max_size=3, unique_by=lambda t: t[0]))
    def test_revive_never_oversells_sweep(seed, kills):
        """Random kill/revive schedules, lease detection only (no caller
        mask): stock stays nonnegative at every window, the ledgers stay
        exact, and the quiescent audit (conservation + never-oversell +
        escrow-covers-stock) passes — false suspicion and revival can waste
        throughput but can never manufacture admission capacity."""
        sim = _chaos_sim(reserve=True, seed=seed, stock_scale=4)
        schedule = {}
        for replica, at, dur in kills:
            schedule[at] = schedule.get(at, []) + [(replica, dur)]
        revive_at = {}
        for w in range(10):
            for replica, dur in schedule.get(w, []):
                sim.kill(replica)
                revive_at.setdefault(w + dur, []).append(replica)
            for replica in revive_at.get(w, []):
                sim.revive(replica)
            _window(sim, batch=8)
            assert bool((sim.full_state().s_quantity >= 0).all())
            led = sim.cold_ledger()
            assert led["exact"] and led["reservations_exact"], led
        for replicas in revive_at.values():
            for replica in replicas:
                if not sim.up[replica]:
                    sim.revive(replica)
        for _ in range(sim.monitor.detection_bound + 1):
            _window(sim, batch=8)
        _quiesce_and_check(sim)


# ---------------------------------------------------------------------------
# Driver wiring + the HLO collective budget with liveness/reserve on
# ---------------------------------------------------------------------------


def test_run_loop_liveness_matches_caller_mask():
    """run_loop(liveness=...) with an always-beating monitor is bit-exact
    to the alive=None run — the self-derived all-alive mask and the
    implicit one compile and execute to the same refresh."""
    from repro.txn.drivers import run_loop
    from repro.txn.engine import single_host_engine

    scale = _scale()
    eng = single_host_engine(scale, stock_invariant="strict")
    state0 = eng.shard_state(tpcc.init_state(scale, seed=0))
    kw = dict(batch_per_shard=8, n_batches=8, remote_frac=0.5,
              merge_every=4, refresh_every=1, seed=7, retry_cap=32,
              retry_max=2)

    def always_beating():
        mon = LeaseMonitor(eng.n_shards)
        seq = {"n": 0}

        def source(window):
            seq["n"] += 1
            return np.asarray([int(pack_lease_stamp(0, seq["n"]))]
                              * eng.n_shards, np.int64)
        mon.source = source
        return mon

    s_ref, e_ref, _ = run_loop(eng, jax.tree.map(jnp.copy, state0), **kw)
    mon = always_beating()
    s_liv, e_liv, _ = run_loop(eng, jax.tree.map(jnp.copy, state0),
                               liveness=mon, **kw)
    assert mon.window > 0, "monitor was never ticked"
    for a, b in zip(jax.tree.leaves((s_ref, e_ref)),
                    jax.tree.leaves((s_liv, e_liv))):
        assert bool((a == b).all())
    # dispatch mode threads the same wiring
    mon2 = always_beating()
    s_d, e_d, _ = run_loop(eng, jax.tree.map(jnp.copy, state0), fused=False,
                           liveness=mon2, **kw)
    assert mon2.window > 0


def test_hot_path_collective_free_with_liveness_and_reserve():
    """Acceptance: the hot path stays HLO-proved collective-free with the
    liveness layer on (heartbeats are host-resident metadata riding the
    drain — the compiled megastep is untouched), and the reserve-enabled
    retry drain keeps the exact collective budget of the plain strict
    drain (reservations are owner-local, never gathered)."""
    from repro.txn.engine import single_host_engine
    from repro.txn.executor import get_fused_executor

    eng = single_host_engine(_scale(), stock_invariant="strict")
    ex = get_fused_executor(eng, ring_rows=4, retry_cap=16)
    ex.prove_megastep_coordination_free(chunk_len=4, batch_per_shard=8)
    plain = ex.count_drain_strict_collectives(8)
    with_reserve = ex.count_drain_strict_retry_collectives(8)
    assert dict(with_reserve.counts) == dict(plain.counts)


def test_obs_session_reports_detection_latency():
    """Detection lags feed the obs plane as a histogram lattice: the
    session snapshot grows a detection_latency summary, and joins from two
    monitors merge commutatively."""
    from repro.obs import ObsSession
    from repro.obs.metrics import (heartbeat_lag_histogram,
                                   heartbeat_lag_summary)
    from repro.core.lattice import HistogramLattice

    sess = ObsSession(metrics=False, trace=False)
    sess.record_heartbeat_lags([3, 3, 4])
    sess.record_heartbeat_lags([2])
    snap = sess.snapshot()
    assert snap["detection_latency"]["count"] == 4
    assert snap["detection_latency"]["p99_windows"] >= 4
    a = heartbeat_lag_histogram([1, 5])
    b = heartbeat_lag_histogram([8])
    ab = HistogramLattice.join(a, b)
    ba = HistogramLattice.join(b, a)
    assert np.array_equal(np.asarray(ab.counts), np.asarray(ba.counts))
    assert heartbeat_lag_summary(ab)["count"] == 3
