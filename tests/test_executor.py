"""Fused megastep executor (txn/executor.py):

* bit-exact final-state equivalence vs the per-batch dispatch driver on a
  fixed seed (same pre-generated stream, same drain cadence);
* the hot scan's compiled HLO contains ZERO collective ops while the drain
  (off the hot path) is the only communicating program;
* donation actually consumes the input buffers (no doubled live state) and
  the compiled module carries input/output aliasing;
* reduced mixes (no reads / no payments / no deliveries) and ragged tail
  chunks execute correctly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.txn.audit import assert_audit
from repro.txn.engine import (run_closed_loop, run_escrow_loop,
                              run_mixed_loop, single_host_engine)
from repro.txn.executor import (FusedExecutor, MixChunk, counters_to_stats,
                                run_fused_loop, stack_chunks)
from repro.txn.engine import generate_mix_batches
from repro.txn.tpcc import TPCCScale, check_consistency, init_state

SCALE = TPCCScale(n_warehouses=4, districts=4, customers=8, n_items=64,
                  order_capacity=128, max_lines=15)


@pytest.fixture(scope="module")
def engine():
    return single_host_engine(SCALE)


@pytest.fixture(scope="module")
def escrow_engine():
    return single_host_engine(SCALE, stock_invariant="strict")


def _tree_equal(a, b):
    eq = jax.tree.map(lambda x, y: bool((x == y).all()), a, b)
    return [f for f, ok in zip(a._fields, eq) if not ok]


def test_fused_bitexact_vs_dispatch(engine):
    """The tentpole equivalence: identical stream, identical cadence =>
    bit-identical final state and identical MixStats counters — including a
    ragged tail chunk (10 batches, merge_every=4 -> chunks of 4, 4, 2)."""
    kw = dict(batch_per_shard=8, n_batches=10, merge_every=4,
              remote_frac=0.3, read_frac=0.25, seed=3)
    s1 = engine.shard_state(init_state(SCALE))
    s1, m1 = run_mixed_loop(engine, s1, fused=False, **kw)
    s2 = engine.shard_state(init_state(SCALE))
    s2, m2 = run_mixed_loop(engine, s2, fused=True, **kw)

    assert _tree_equal(s1, s2) == []
    for f in ("neworders", "payments", "order_statuses", "stock_levels",
              "deliveries", "anti_entropy_rounds", "reads_found",
              "fractures_observed", "lines_repaired"):
        assert getattr(m1, f) == getattr(m2, f), f
    assert m2.fractures_observed == 0  # RAMP atomic visibility holds fused
    assert all(check_consistency(s2).values())
    assert_audit(s2)


def test_escrow_fused_dispatch_legacy_bitexact(escrow_engine):
    """The escrow-regime equivalence, three ways: fused (escrow counters in
    the donated scan carry, refresh fused into the drain), per-batch
    dispatch, and legacy (per-outbox drains, per-batch host stat reads) run
    the identical stream at the identical drain/refresh cadence and land on
    bit-identical state, escrow counters, and MixStats — including a ragged
    tail chunk and a non-trivial refresh cadence."""
    eng = escrow_engine
    kw = dict(batch_per_shard=8, n_batches=10, merge_every=4,
              refresh_every=2, remote_frac=0.3, read_frac=0.25, seed=3,
              mix=True)
    finals = {}
    for name, mode in (("fused", dict(fused=True)),
                       ("dispatch", dict(fused=False)),
                       ("legacy", dict(legacy=True))):
        s = eng.shard_state(init_state(SCALE))
        q0 = s.s_quantity.copy()
        finals[name] = run_escrow_loop(eng, s, **mode, **kw)
    s_f, esc_f, m_f = finals["fused"]
    for other in ("dispatch", "legacy"):
        s_o, esc_o, m_o = finals[other]
        assert _tree_equal(s_f, s_o) == [], other
        assert _tree_equal(esc_f, esc_o) == [], other
        for f in ("neworders", "aborts", "payments", "order_statuses",
                  "stock_levels", "deliveries", "anti_entropy_rounds",
                  "refreshes", "reads_found", "fractures_observed",
                  "lines_repaired"):
            assert getattr(m_f, f) == getattr(m_o, f), (other, f)
    assert m_f.aborts > 0              # adversarial: demand exceeds shares
    assert m_f.refreshes == 1          # 3 drains, refresh_every=2
    assert m_f.fractures_observed == 0
    assert_audit(s_f, escrow=esc_f, initial_stock=q0, strict_stock=True)


def test_escrow_megastep_zero_collectives(escrow_engine):
    """The escrow hot path between refreshes — merge_every full-mix
    iterations including the try_spend admission scan — compiles with ZERO
    collective ops; the fused drain+refresh is the only communicating
    program of the regime."""
    ex = FusedExecutor(escrow_engine, ring_rows=4)
    desc = ex.prove_megastep_coordination_free(chunk_len=4, batch_per_shard=4,
                                               read_per_shard=2)
    assert "NONE" in desc
    assert ex.count_drain_refresh_collectives(4).total_ops > 0
    # escrow executors refuse the free-regime entry points and vice versa
    state = escrow_engine.shard_state(init_state(SCALE))
    with pytest.raises(RuntimeError, match="use run_escrow"):
        ex.run(state, [])
    ex_free = FusedExecutor(single_host_engine(SCALE), ring_rows=4)
    with pytest.raises(RuntimeError, match="use run"):
        ex_free.run_escrow(state, None, [])


def test_megastep_hot_scan_zero_collectives(engine):
    """Definition 5 on the fused path: merge_every full-mix iterations
    compile with no collective ops; the chunk drain is where (all of) the
    communication lives."""
    ex = FusedExecutor(engine, ring_rows=4)
    desc = ex.prove_megastep_coordination_free(chunk_len=4, batch_per_shard=4,
                                               read_per_shard=2)
    assert "NONE" in desc
    # symmetric check on a multi-shard mesh lives in
    # test_engine.py::test_multi_device_proof_subprocess; here the drain
    # must at least compile and clear the ring
    state = engine.shard_state(init_state(SCALE))
    ring = ex.init_ring(4)
    state, ring2 = ex.drain(state, ring)
    assert not bool(jax.device_get(ring2.valid).any())


def test_megastep_donation_reuses_buffers(engine):
    """Donated state/ring/counters: inputs are consumed (buffers deleted,
    not copied) and the compiled module aliases inputs to outputs."""
    ex = FusedExecutor(engine, ring_rows=2)
    no_b, pay_b, os_b, sl_b = generate_mix_batches(
        engine, batch_per_shard=4, n_batches=2, seed=0)
    chunk = stack_chunks(no_b, pay_b, os_b, sl_b, 2)[0]
    state = engine.shard_state(init_state(SCALE))
    ring, counters = ex.init_ring(4), ex.init_counters()

    out = ex.megastep(state, ring, counters, chunk)
    assert state.s_ytd.is_deleted(), "donated state buffer survived"
    assert ring.valid.is_deleted(), "donated ring buffer survived"
    assert counters.neworders.is_deleted(), "donated counter buffer survived"
    text = ex.lowered_megastep(chunk_len=2, batch_per_shard=4,
                               read_per_shard=1).compile().as_text()
    assert "input_output_alias" in text

    state2, ring2 = ex.drain(out[0], out[1])
    assert out[0].s_ytd.is_deleted(), "drain did not consume donated state"
    jax.block_until_ready((state2, ring2))


def test_counters_accumulate_on_device(engine):
    """MixStats comes from ONE device_get over the counter pytree."""
    state = engine.shard_state(init_state(SCALE))
    no_b, pay_b, os_b, sl_b = generate_mix_batches(
        engine, batch_per_shard=8, n_batches=4, seed=7)
    ex = FusedExecutor(engine, ring_rows=4)
    chunks = stack_chunks(no_b, pay_b, os_b, sl_b, 4)
    state, counters, wall = ex.run(state, chunks)
    assert isinstance(counters.neworders, jax.Array)
    stats = counters_to_stats(counters, anti_entropy_rounds=len(chunks),
                              wall_seconds=wall)
    assert stats.neworders == 8 * 4
    assert stats.payments == 8 * 4
    assert stats.order_statuses == stats.stock_levels == 2 * 4
    assert stats.fractures_observed == 0
    assert stats.deliveries > 0


def test_reduced_mix_chunks(engine):
    """None-valued chunk fields statically drop transactions from the scan:
    the New-Order-only closed loop and a payment-less mix both run."""
    kw = dict(batch_per_shard=8, n_batches=6, merge_every=3, seed=11)
    s1 = engine.shard_state(init_state(SCALE))
    s1, r1 = run_closed_loop(engine, s1, fused=True, **kw)
    s2 = engine.shard_state(init_state(SCALE))
    s2, r2 = run_closed_loop(engine, s2, fused=False, **kw)
    assert _tree_equal(s1, s2) == []
    assert r1.committed == r2.committed == 8 * 6
    assert r1.anti_entropy_rounds == r2.anti_entropy_rounds == 2

    # payments+deliveries variant stays consistent end-to-end
    s3 = engine.shard_state(init_state(SCALE))
    s3, _ = run_closed_loop(engine, s3, payments=True, deliveries=True,
                            fused=True, **kw)
    assert all(check_consistency(s3).values())
    assert_audit(s3)


def test_chunk_longer_than_ring_rejected(engine):
    ex = FusedExecutor(engine, ring_rows=2)
    no_b, pay_b, os_b, sl_b = generate_mix_batches(
        engine, batch_per_shard=4, n_batches=3, seed=0)
    chunk = stack_chunks(no_b, pay_b, os_b, sl_b, 3)[0]
    state = engine.shard_state(init_state(SCALE))
    with pytest.raises(ValueError, match="exceeds"):
        ex.megastep(state, ex.init_ring(4), ex.init_counters(), chunk)


def test_fused_loop_direct_api(engine):
    """run_fused_loop is the public entry run_mixed_loop(fused=True) uses."""
    state = engine.shard_state(init_state(SCALE))
    state, stats = run_fused_loop(engine, state, batch_per_shard=8,
                                  n_batches=8, merge_every=8, seed=2)
    assert stats.neworders == 64
    assert stats.anti_entropy_rounds == 1
    assert stats.throughput > 0
    assert all(check_consistency(state).values())
    assert_audit(state)
