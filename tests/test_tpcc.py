"""TPC-C substrate: transaction effects, the twelve criteria, analyzer audit."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.txn import tpcc
from repro.txn.tpcc import (TPCCScale, apply_delivery, apply_neworder,
                            apply_payment, check_consistency,
                            generate_neworder, generate_payment, init_state,
                            tpcc_invariants)

SCALE = TPCCScale(n_warehouses=2, districts=4, customers=8, n_items=32,
                  order_capacity=64, max_lines=15)


def test_initial_state_consistent():
    state = init_state(SCALE)
    assert all(check_consistency(state).values())


def test_neworder_sequential_ids_within_batch():
    """Batched increment-and-get: same-district txns get consecutive ids."""
    state = init_state(SCALE)
    rng = np.random.default_rng(0)
    batch = generate_neworder(rng, SCALE, 16, remote_frac=0.0)
    # force all into one district to maximize contention
    batch = batch._replace(w=jnp.zeros(16, jnp.int32),
                           d=jnp.zeros(16, jnp.int32))
    state, delta, total = apply_neworder(state, batch, SCALE)
    assert int(state.d_next_o_id[0, 0]) == 16
    # all 16 orders present, ids dense
    assert int(state.o_valid[0, 0].sum()) == 16
    assert not bool(delta.valid.any())  # no remote lines
    assert all(check_consistency(state).values())


def test_neworder_totals_match_prices():
    state = init_state(SCALE)
    rng = np.random.default_rng(1)
    batch = generate_neworder(rng, SCALE, 4, remote_frac=0.0)
    state2, _, total = apply_neworder(state, batch, SCALE)
    s = jax.device_get(state)
    b = jax.device_get(batch)
    for i in range(4):
        L = b.n_lines[i]
        amount = (s.i_price[b.w[i], b.i_id[i, :L]] * b.qty[i, :L]).sum()
        expect = amount * (1 - s.c_discount[b.w[i], b.d[i], b.c[i]]) \
            * (1 + s.w_tax[b.w[i]] + s.d_tax[b.w[i], b.d[i]])
        assert float(total[i]) == pytest.approx(float(expect), rel=1e-5)


def test_stock_restock_rule():
    """S_QUANTITY stays >= 10 - never negative - via the +91 restock."""
    state = init_state(SCALE)
    rng = np.random.default_rng(2)
    for ts in range(6):
        batch = generate_neworder(rng, SCALE, 32, remote_frac=0.0, ts0=ts * 32)
        state, _, _ = apply_neworder(state, batch, SCALE)
    q = np.asarray(state.s_quantity)
    assert q.min() >= 0
    ytd = np.asarray(state.s_ytd)
    assert ytd.sum() > 0  # updates actually landed


def test_remote_lines_go_to_outbox_not_state():
    state = init_state(SCALE)
    rng = np.random.default_rng(3)
    batch = generate_neworder(rng, SCALE, 8, remote_frac=1.0)
    # treat warehouse 0 as the local shard
    state2, delta, _ = apply_neworder(state, batch, SCALE, w_lo=0, w_hi=1)
    b = jax.device_get(batch)
    n_remote = int(((b.supply_w != 0) &
                    (np.arange(15)[None, :] < b.n_lines[:, None])).sum())
    assert int(jax.device_get(delta.valid).sum()) == n_remote
    # outbox entries correspond positionally to the remote lines (the drain
    # applies by valid mask; the old dense-prefix compaction is gone)
    v = np.asarray(delta.valid).reshape(8, 15)
    remote = (b.supply_w != 0) & (np.arange(15)[None, :] < b.n_lines[:, None])
    assert np.array_equal(v, remote)
    assert np.array_equal(np.asarray(delta.dst_w).reshape(8, 15)[remote],
                          b.supply_w[remote])


def test_payment_maintains_materialized_sums():
    state = init_state(SCALE)
    rng = np.random.default_rng(4)
    for _ in range(3):
        state = apply_payment(state, generate_payment(rng, SCALE, 16))
    c = check_consistency(state)
    assert c[1] and c[8] and c[9] and c[10] and c[12], c


def test_delivery_oldest_first_and_criteria():
    state = init_state(SCALE)
    rng = np.random.default_rng(5)
    batch = generate_neworder(rng, SCALE, 24, remote_frac=0.0)
    state, _, _ = apply_neworder(state, batch, SCALE)
    before = int(state.no_valid.sum())
    state = apply_delivery(state, jnp.asarray(7, jnp.int32), jnp.asarray(1, jnp.int32))
    after = int(state.no_valid.sum())
    # one delivery per district that had an undelivered order
    had = int((jax.device_get(state.o_valid).any(-1)).sum() > 0)
    assert after < before
    c = check_consistency(state)
    assert all(c.values()), c
    # delivered orders have carrier set and lines marked
    s = jax.device_get(state)
    delivered = s.o_valid & ~s.no_valid
    assert np.all(s.o_carrier[delivered] == 7)


def test_twelve_criteria_classification():
    """The paper's headline: 10 of 12 TPC-C invariants are I-confluent."""
    from repro.core.analyzer import classify
    from repro.core.txn import Op, OpKind

    rows = tpcc_invariants()
    assert len(rows) == 12
    confluent = [expected for (_, _, expected) in rows]
    assert sum(confluent) == 10
    # the two non-confluent ones are the sequential-ID criteria 2 and 3
    bad = [n for (n, _, expected) in rows if not expected]
    assert bad == [2, 3]
    # and the analyzer agrees with each expected classification
    for n, inv, expected in rows:
        op = Op(OpKind.INSERT)
        v = classify(inv, op)
        assert v.coordination_free == expected, (n, inv.name, v)


def test_full_mix_consistency_after_interleaving():
    """New-Order + Payment + Delivery interleaved; criteria hold throughout."""
    state = init_state(SCALE)
    rng = np.random.default_rng(6)
    ts = 0
    for round_ in range(4):
        no = generate_neworder(rng, SCALE, 16, remote_frac=0.0, ts0=ts)
        ts += 16
        state, _, _ = apply_neworder(state, no, SCALE)
        state = apply_payment(state, generate_payment(rng, SCALE, 8))
        if round_ % 2:
            state = apply_delivery(state, jnp.asarray(round_, jnp.int32),
                                   jnp.asarray(ts, jnp.int32))
        c = check_consistency(state)
        assert all(c.values()), (round_, c)
