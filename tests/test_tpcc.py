"""TPC-C substrate: transaction effects, the twelve criteria, analyzer audit."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.txn import tpcc
from repro.txn.audit import assert_audit, audit_tpcc
from repro.txn.tpcc import (TPCCScale, apply_delivery, apply_neworder,
                            apply_neworder_escrow, apply_payment,
                            check_consistency, generate_neworder,
                            generate_payment, init_state, make_escrow_shares,
                            tpcc_invariants)

SCALE = TPCCScale(n_warehouses=2, districts=4, customers=8, n_items=32,
                  order_capacity=64, max_lines=15)


def test_initial_state_consistent():
    state = init_state(SCALE)
    assert all(check_consistency(state).values())


def test_neworder_sequential_ids_within_batch():
    """Batched increment-and-get: same-district txns get consecutive ids."""
    state = init_state(SCALE)
    rng = np.random.default_rng(0)
    batch = generate_neworder(rng, SCALE, 16, remote_frac=0.0)
    # force all into one district to maximize contention
    batch = batch._replace(w=jnp.zeros(16, jnp.int32),
                           d=jnp.zeros(16, jnp.int32))
    state, delta, total = apply_neworder(state, batch, SCALE)
    assert int(state.d_next_o_id[0, 0]) == 16
    # all 16 orders present, ids dense
    assert int(state.o_valid[0, 0].sum()) == 16
    assert not bool(delta.valid.any())  # no remote lines
    assert all(check_consistency(state).values())


def test_neworder_totals_match_prices():
    state = init_state(SCALE)
    rng = np.random.default_rng(1)
    batch = generate_neworder(rng, SCALE, 4, remote_frac=0.0)
    state2, _, total = apply_neworder(state, batch, SCALE)
    s = jax.device_get(state)
    b = jax.device_get(batch)
    for i in range(4):
        L = b.n_lines[i]
        amount = (s.i_price[b.w[i], b.i_id[i, :L]] * b.qty[i, :L]).sum()
        expect = amount * (1 - s.c_discount[b.w[i], b.d[i], b.c[i]]) \
            * (1 + s.w_tax[b.w[i]] + s.d_tax[b.w[i], b.d[i]])
        assert float(total[i]) == pytest.approx(float(expect), rel=1e-5)


def test_stock_restock_rule():
    """S_QUANTITY stays >= 10 - never negative - via the +91 restock."""
    state = init_state(SCALE)
    rng = np.random.default_rng(2)
    for ts in range(6):
        batch = generate_neworder(rng, SCALE, 32, remote_frac=0.0, ts0=ts * 32)
        state, _, _ = apply_neworder(state, batch, SCALE)
    q = np.asarray(state.s_quantity)
    assert q.min() >= 0
    ytd = np.asarray(state.s_ytd)
    assert ytd.sum() > 0  # updates actually landed


def test_remote_lines_go_to_outbox_not_state():
    state = init_state(SCALE)
    rng = np.random.default_rng(3)
    batch = generate_neworder(rng, SCALE, 8, remote_frac=1.0)
    # treat warehouse 0 as the local shard
    state2, delta, _ = apply_neworder(state, batch, SCALE, w_lo=0, w_hi=1)
    b = jax.device_get(batch)
    n_remote = int(((b.supply_w != 0) &
                    (np.arange(15)[None, :] < b.n_lines[:, None])).sum())
    assert int(jax.device_get(delta.valid).sum()) == n_remote
    # outbox entries correspond positionally to the remote lines (the drain
    # applies by valid mask; the old dense-prefix compaction is gone)
    v = np.asarray(delta.valid).reshape(8, 15)
    remote = (b.supply_w != 0) & (np.arange(15)[None, :] < b.n_lines[:, None])
    assert np.array_equal(v, remote)
    assert np.array_equal(np.asarray(delta.dst_w).reshape(8, 15)[remote],
                          b.supply_w[remote])


def test_payment_maintains_materialized_sums():
    state = init_state(SCALE)
    rng = np.random.default_rng(4)
    for _ in range(3):
        state = apply_payment(state, generate_payment(rng, SCALE, 16))
    c = check_consistency(state)
    assert c[1] and c[8] and c[9] and c[10] and c[12], c


def test_delivery_oldest_first_and_criteria():
    state = init_state(SCALE)
    rng = np.random.default_rng(5)
    batch = generate_neworder(rng, SCALE, 24, remote_frac=0.0)
    state, _, _ = apply_neworder(state, batch, SCALE)
    before = int(state.no_valid.sum())
    state = apply_delivery(state, jnp.asarray(7, jnp.int32), jnp.asarray(1, jnp.int32))
    after = int(state.no_valid.sum())
    # one delivery per district that had an undelivered order
    had = int((jax.device_get(state.o_valid).any(-1)).sum() > 0)
    assert after < before
    c = check_consistency(state)
    assert all(c.values()), c
    # delivered orders have carrier set and lines marked
    s = jax.device_get(state)
    delivered = s.o_valid & ~s.no_valid
    assert np.all(s.o_carrier[delivered] == 7)


def test_twelve_criteria_classification():
    """The paper's headline: 10 of 12 TPC-C invariants are I-confluent."""
    from repro.core.analyzer import classify
    from repro.core.txn import Op, OpKind

    rows = tpcc_invariants()
    assert len(rows) == 12
    confluent = [expected for (_, _, expected) in rows]
    assert sum(confluent) == 10
    # the two non-confluent ones are the sequential-ID criteria 2 and 3
    bad = [n for (n, _, expected) in rows if not expected]
    assert bad == [2, 3]
    # and the analyzer agrees with each expected classification
    for n, inv, expected in rows:
        op = Op(OpKind.INSERT)
        v = classify(inv, op)
        assert v.coordination_free == expected, (n, inv.name, v)


def test_full_mix_consistency_after_interleaving():
    """New-Order + Payment + Delivery interleaved; criteria hold throughout."""
    state = init_state(SCALE)
    rng = np.random.default_rng(6)
    ts = 0
    for round_ in range(4):
        no = generate_neworder(rng, SCALE, 16, remote_frac=0.0, ts0=ts)
        ts += 16
        state, _, _ = apply_neworder(state, no, SCALE)
        state = apply_payment(state, generate_payment(rng, SCALE, 8))
        if round_ % 2:
            state = apply_delivery(state, jnp.asarray(round_, jnp.int32),
                                   jnp.asarray(ts, jnp.int32))
        c = check_consistency(state)
        assert all(c.values()), (round_, c)
    assert_audit(state)


# -- strict-stock (escrow) New-Order variant ---------------------------------


def test_escrow_neworder_atomic_aborts_and_dense_ids():
    """Insufficient escrow aborts the WHOLE transaction (no partial
    effects), committed transactions still get dense sequential o_ids, and
    s_quantity never goes negative (no restock)."""
    state = init_state(SCALE)
    q0 = np.asarray(state.s_quantity).copy()
    shares = make_escrow_shares(state.s_quantity, 1)[0]
    spent = jnp.zeros_like(shares)
    rng = np.random.default_rng(9)
    committed_total = 0
    for ts in range(8):
        b = generate_neworder(rng, SCALE, 16, remote_frac=0.0, ts0=ts * 16)
        state, spent, delta, total, ok = apply_neworder_escrow(
            state, shares, spent, b, SCALE)
        assert not bool(np.asarray(delta.valid).any())  # all lines local
        # aborted txns return zero totals
        assert np.all(np.asarray(total)[~np.asarray(ok)] == 0.0)
        committed_total += int(ok.sum())
    s = jax.device_get(state)
    assert 0 < committed_total < 8 * 16      # adversarial stream: some abort
    assert s.s_quantity.min() >= 0
    # dense ids: d_next_o_id counts exactly the committed orders
    assert int(s.d_next_o_id.sum()) == committed_total
    assert int(s.o_valid.sum()) == committed_total
    # conservation: every admitted unit left stock exactly once
    assert np.array_equal(s.s_quantity + np.rint(s.s_ytd).astype(np.int32),
                          q0)
    assert np.array_equal(np.asarray(spent), q0 - s.s_quantity)
    assert all(check_consistency(state).values())
    assert_audit(state, initial_stock=q0, strict_stock=True)


def test_escrow_neworder_respects_share_not_global_stock():
    """A replica may only spend from ITS share: with the budget split
    across 4 replicas, replica 0 aborts once its quarter is gone even
    though global stock remains."""
    state = init_state(SCALE)
    shares = make_escrow_shares(state.s_quantity, 4)  # [4, W, I]
    spent0 = jnp.zeros_like(shares[0])
    rng = np.random.default_rng(3)
    state, spent0, _, _, ok = apply_neworder_escrow(
        state, shares[0], spent0, generate_neworder(rng, SCALE, 64,
                                                    remote_frac=0.0),
        SCALE, replica=0, num_replicas=4)
    # replica 0 stayed within its quarter ...
    assert np.all(np.asarray(spent0) <= np.asarray(shares[0]))
    # ... and the quarter is binding: strictly fewer commits than the full
    # budget admits on the same stream
    state2 = init_state(SCALE)
    full = make_escrow_shares(state2.s_quantity, 1)[0]
    _, _, _, _, ok_full = apply_neworder_escrow(
        state2, full, jnp.zeros_like(full),
        generate_neworder(np.random.default_rng(3), SCALE, 64,
                          remote_frac=0.0), SCALE)
    assert int(ok.sum()) < int(ok_full.sum())


def test_audit_oracle_catches_violations():
    """The auditor is not a rubber stamp: corrupting the state flips it."""
    state = init_state(SCALE)
    q0 = np.asarray(state.s_quantity).copy()
    assert audit_tpcc(state, initial_stock=q0, strict_stock=True).ok
    # negative stock
    bad = state._replace(s_quantity=state.s_quantity.at[0, 0].set(-1))
    rep = audit_tpcc(bad, initial_stock=q0, strict_stock=True)
    assert not rep.ok and "stock_nonnegative" in rep.failures
    # phantom spend (conservation broken)
    bad2 = state._replace(s_ytd=state.s_ytd.at[0, 0].add(5.0))
    rep2 = audit_tpcc(bad2, initial_stock=q0, strict_stock=True)
    assert not rep2.ok and "stock_conservation" in rep2.failures
    # order-count drift
    bad3 = state._replace(d_next_o_id=state.d_next_o_id.at[0, 0].add(1))
    rep3 = audit_tpcc(bad3)
    assert not rep3.ok and "d_next_o_id_counts_orders" in rep3.failures
