"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.lattice_merge import lattice_merge_kernel
from repro.kernels.rwkv6_scan import rwkv6_scan_kernel


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, S, H, KV, hd, dtype, causal, bq, bk)
    (1, 32, 2, 2, 16, jnp.float32, True, 8, 8),
    (2, 64, 4, 2, 32, jnp.float32, True, 16, 16),
    (2, 64, 4, 1, 32, jnp.float32, False, 32, 16),
    (1, 128, 8, 2, 64, jnp.float32, True, 64, 32),
    (1, 64, 4, 4, 64, jnp.bfloat16, True, 16, 16),
    (2, 48, 6, 3, 16, jnp.float32, True, 16, 16),  # uneven heads/groups
    (1, 128, 2, 2, 128, jnp.float32, False, 128, 128),
]


@pytest.mark.parametrize("B,S,H,KV,hd,dtype,causal,bq,bk", ATTN_CASES)
def test_flash_attention_sweep(B, S, H, KV, hd, dtype, causal, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention_kernel(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_ops_wrapper_matches_layers_attend():
    """The kernel path must agree with the model's jnp attention."""
    from repro.models.layers import attend
    B, S, H, KV, hd = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    pos = jnp.arange(S)
    o1 = attend(q, k, v, pos, pos, causal=True, use_flash=False)
    o2 = ops.flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000),
       s_pow=st.integers(4, 6), causal=st.booleans())
def test_flash_attention_property(seed, s_pow, causal):
    S = 2 ** s_pow
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, S, 2, 16))
    k = jax.random.normal(ks[1], (1, S, 2, 16))
    v = jax.random.normal(ks[2], (1, S, 2, 16))
    out = flash_attention_kernel(q, k, v, causal=causal, block_q=16,
                                 block_k=16, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

RWKV_CASES = [
    # (B, T, H, hd, chunk, dtype)
    (1, 16, 1, 8, 4, jnp.float32),
    (2, 32, 2, 16, 8, jnp.float32),
    (2, 64, 4, 32, 16, jnp.float32),
    (1, 64, 2, 64, 64, jnp.float32),
    (1, 32, 2, 16, 8, jnp.bfloat16),
]


@pytest.mark.parametrize("B,T,H,hd,chunk,dtype", RWKV_CASES)
def test_rwkv6_scan_sweep(B, T, H, hd, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, H, hd), dtype)
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.5 + 0.4
         ).astype(dtype)
    u = (jax.random.normal(ks[4], (H, hd)) * 0.1).astype(jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    out, sT = rwkv6_scan_kernel(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    want, sT_want = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    # f32 tolerance is relative: long chunks accumulate values of O(50)
    tol = dict(rtol=1e-3, atol=5e-4) if dtype != jnp.bfloat16 else _tol(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_want),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 2e-4,
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-4)


def test_rwkv6_scan_nonzero_initial_state():
    B, T, H, hd = 1, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.4 + 0.5
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.2
    out, sT = rwkv6_scan_kernel(r, k, v, w, u, s0, chunk=4, interpret=True)
    want, sT_want = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_rwkv6_kernel_matches_model_path():
    """ops.rwkv6_scan must agree with the model's wkv_chunked oracle."""
    from repro.models.rwkv6 import wkv_chunked
    B, T, H, hd = 2, 32, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    s0 = jnp.zeros((B, H, hd, hd))
    o1, s1 = wkv_chunked(r, k, v, w, u, s0, chunk=8)
    o2, s2 = ops.rwkv6_scan(r, k, v, w, u, s0, chunk=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# lattice merge
# ---------------------------------------------------------------------------

MERGE_CASES = [
    (64, 4, jnp.float32, 16),
    (256, 8, jnp.float32, 64),
    (128, 2, jnp.bfloat16, 128),
    (512, 1, jnp.float32, 256),
]


@pytest.mark.parametrize("R,W,dtype,block", MERGE_CASES)
def test_lattice_merge_sweep(R, W, dtype, block):
    rng = np.random.default_rng(0)
    a_valid = jnp.asarray(rng.random(R) < 0.7)
    b_valid = jnp.asarray(rng.random(R) < 0.7)
    a_ver = jnp.asarray(rng.integers(-1, 50, R).astype(np.int32))
    b_ver = jnp.asarray(rng.integers(-1, 50, R).astype(np.int32))
    a_pay = jnp.asarray(rng.normal(0, 3, (R, W)).astype(np.float32)).astype(dtype)
    b_pay = jnp.asarray(rng.normal(0, 3, (R, W)).astype(np.float32)).astype(dtype)
    lo, hi = -5.0, 5.0

    got = lattice_merge_kernel(a_valid, a_ver, a_pay, b_valid, b_ver, b_pay,
                               lo, hi, block_rows=block, interpret=True)
    want = ref.lattice_merge_ref(a_valid, a_ver, a_pay, b_valid, b_ver, b_pay,
                                 lo, hi)
    for g, w_ in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w_))


def test_lattice_merge_is_lattice_join():
    """Kernel output must equal core.lattice.VersionedSlots.join."""
    from repro.core.lattice import VersionedSlots
    rng = np.random.default_rng(1)
    R, W = 128, 4
    def mk(r):
        return VersionedSlots(
            jnp.asarray(rng.random(R) < 0.6),
            jnp.asarray(((rng.integers(0, 50, R)) * 4 + r).astype(np.int64)),
            jnp.asarray(rng.normal(0, 1, (R, W)).astype(np.float32)))
    a, b = mk(0), mk(1)
    want = VersionedSlots.join(a, b)
    valid, ver, pay, viol = ops.lattice_merge(
        a.valid, a.version.astype(jnp.int32), a.payload,
        b.valid, b.version.astype(jnp.int32), b.payload,
        lo=-1e9, hi=1e9)
    np.testing.assert_array_equal(np.asarray(valid), np.asarray(want.valid))
    np.testing.assert_array_equal(np.asarray(pay), np.asarray(want.payload))
    assert not bool(viol.any())
