"""Coordination planner tests: runtime state trees classified correctly."""

import jax.numpy as jnp
import pytest

from repro.core import merge as merge_mod
from repro.core.invariants import Invariant, InvariantKind
from repro.core.lattice import GCounter
from repro.core.planner import (CoordClass, StateSpec, plan_state, plan_states,
                                serving_state_specs, training_state_specs)
from repro.core.txn import Op, OpKind


def test_training_plan_hierarchical():
    plan = plan_states(training_state_specs(coord_mode="hierarchical",
                                            merge_every=8))
    # gradients are confluent (sum merge, view invariant)
    assert plan.entry("grads").coord_class is CoordClass.FREE
    assert plan.entry("grads").spec.merge_every == 8
    # monotone step counter never coordinates
    assert plan.entry("step").coord_class is CoordClass.FREE
    # metrics free
    assert plan.entry("metrics.loss_sum").coord_class is CoordClass.FREE
    # sample ids free via replica namespacing
    assert plan.entry("sample_ids").coord_class is CoordClass.FREE
    # loss scale: increments against overflow ceiling -> escrow
    assert plan.entry("loss_scale").coord_class is CoordClass.ESCROW
    # checkpoint sequential IDs -> escrow-able (deferred assignment)
    assert plan.entry("ckpt.sequence_id").coord_class is CoordClass.ESCROW
    # escrow clipping keeps grad_norm off the critical path
    assert plan.entry("grad_norm").coord_class is CoordClass.ESCROW


def test_training_plan_sync_vs_exact_clip():
    plan = plan_states(training_state_specs(coord_mode="sync", exact_clip=True))
    assert plan.entry("grads").spec.merge_every == 1
    assert plan.entry("grad_norm").coord_class is CoordClass.REQUIRED
    assert "grads" in plan.critical_path_collectives()
    assert "grad_norm" in plan.critical_path_collectives()

    plan2 = plan_states(training_state_specs(coord_mode="local_sgd",
                                             merge_every=16, exact_clip=False))
    assert "grads" not in plan2.critical_path_collectives()


def test_serving_plan():
    plan = plan_states(serving_state_specs())
    assert plan.entry("request_ids").coord_class is CoordClass.FREE
    assert plan.entry("admission_budget").coord_class is CoordClass.ESCROW
    assert plan.entry("served_count").coord_class is CoordClass.FREE
    assert plan.entry("batch_slots").coord_class is CoordClass.FREE
    assert not plan.critical_path_collectives()  # serving hot path: zero collectives


def test_plan_summary_renders():
    plan = plan_states(training_state_specs())
    s = plan.summary()
    assert "coordination plan" in s and "grads" in s


def test_uniqueness_specific_forces_required():
    spec = StateSpec("ids", "or",
                     (Op(OpKind.ASSIGN_SPECIFIC, "ids"),),
                     (Invariant("unique", InvariantKind.UNIQUENESS, "ids"),))
    e = plan_state(spec)
    assert e.coord_class is CoordClass.REQUIRED


def test_merge_trees_via_plan_names():
    plan = plan_states([
        StateSpec("count", "gcounter", (Op(OpKind.INCREMENT, "count"),)),
        StateSpec("step", "max", (Op(OpKind.INCREMENT, "step"),)),
    ])
    names = merge_mod.plan_lattice_names(plan)
    a = {"count": GCounter(jnp.asarray([2.0, 0.0])), "step": jnp.asarray(4)}
    b = {"count": GCounter(jnp.asarray([2.0, 3.0])), "step": jnp.asarray(2)}
    m = merge_mod.merge_trees(names, a, b)
    assert float(m["count"].value()) == 5.0
    assert int(m["step"]) == 4


def test_merge_many_balanced_fold():
    names = ("max",)
    states = [{"x": jnp.asarray(i)} for i in (3, 9, 1, 7, 5)]
    m = merge_mod.merge_many(names, states)
    assert int(m["x"]) == 9
    assert merge_mod.converged(names, states)


def test_tpcc_state_specs_plan():
    """The TPC-C state tree plans exactly as the engine consumes it: the
    declared stock invariant is the only knob, and it flips stock between
    the three regimes while everything else stays put."""
    from repro.core.planner import plan
    from repro.txn.tpcc import tpcc_state_specs

    import pytest as _pytest
    for mode, want in (("restock", CoordClass.FREE),
                       ("strict", CoordClass.ESCROW),
                       ("serial", CoordClass.REQUIRED)):
        p = plan(tpcc_state_specs(mode))
        assert p.entry("stock.s_quantity").coord_class is want, mode
        # invariants of the rest of the schema are mode-independent
        assert p.entry("district.d_next_o_id").coord_class \
            is CoordClass.ESCROW  # deferred commit-time assignment
        for free in ("warehouse.w_ytd", "district.d_ytd", "order.rows",
                     "new_order.rows", "order_line.rows",
                     "customer.c_balance", "stock.s_ytd"):
            assert p.entry(free).coord_class is CoordClass.FREE, (mode, free)
    with _pytest.raises(ValueError, match="unknown stock_invariant"):
        tpcc_state_specs("bogus")
