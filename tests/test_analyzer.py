"""Static analyzer tests: Table 2 reproduction + transaction-level analysis."""

import pytest

from repro.core import analyzer as an
from repro.core import invariants as iv
from repro.core import txn as tx
from repro.core.analyzer import Confluence, Strategy, classify, table2
from repro.core.invariants import Invariant, InvariantKind
from repro.core.systems import payroll_transactions
from repro.core.txn import Op, OpKind


def test_table2_matches_paper_exactly():
    """The headline validation: analyzer == paper's Table 2, row for row."""
    rows = table2()
    mismatches = [r for r in rows if not r["match"]]
    assert not mismatches, f"Table 2 mismatches: {mismatches}"
    assert len(rows) == len(an.TABLE2_ROWS)


# The FULL (invariant kind x op kind) grid, frozen: the set of op kinds the
# analyzer must call NOT-confluent for each invariant kind. Paper Table 2
# pins the subset it lists; the remaining cells are the analyzer's documented
# extensions (reads always confluent; unlisted ops that cannot affect the
# invariant are confluent; CUSTOM is conservative). Any drift in classify()
# — and hence in benchmarks/paper_figures.table2 — fails HERE, in tier 1,
# instead of silently changing the benchmark row.
GRID_NOT_CONFLUENT = {
    InvariantKind.EQUALITY: set(),
    InvariantKind.INEQUALITY: set(),
    InvariantKind.UNIQUENESS: {OpKind.INSERT, OpKind.UPDATE,
                               OpKind.ASSIGN_SPECIFIC},
    InvariantKind.AUTO_INCREMENT: {OpKind.INSERT, OpKind.ASSIGN_SPECIFIC,
                                   OpKind.ASSIGN_SOME, OpKind.DELETE,
                                   OpKind.CASCADING_DELETE},
    InvariantKind.FOREIGN_KEY: {OpKind.DELETE},
    InvariantKind.SECONDARY_INDEX: set(),
    InvariantKind.MATERIALIZED_VIEW: set(),
    InvariantKind.GREATER_THAN: {OpKind.DECREMENT},
    InvariantKind.LESS_THAN: {OpKind.INCREMENT},
    InvariantKind.CONTAINS: set(),
    InvariantKind.LIST_POSITION: {OpKind.LIST_MUTATE, OpKind.INSERT,
                                  OpKind.DELETE, OpKind.CASCADING_DELETE,
                                  OpKind.UPDATE},
    InvariantKind.CUSTOM: set(OpKind) - {OpKind.READ},
}


def test_full_grid_parity_with_paper_table():
    """Diff classify() over the ENTIRE (invariant kind x op kind) grid
    against the frozen expectation — and re-derive the paper's Table 2 rows
    from the same grid, so the two can never drift apart."""
    assert set(GRID_NOT_CONFLUENT) == set(InvariantKind)
    mismatches = []
    for kind in InvariantKind:
        for op in OpKind:
            v = classify(Invariant("i", kind), Op(op))
            expected_free = op not in GRID_NOT_CONFLUENT[kind]
            if v.coordination_free != expected_free:
                mismatches.append((kind.value, op.value, str(v)))
    assert not mismatches, mismatches
    # every row the paper's table pins is consistent with the grid
    for label, kind, op_label, op_kind, paper_confluent in an.TABLE2_ROWS:
        assert (op_kind not in GRID_NOT_CONFLUENT[kind]) == paper_confluent, \
            (label, op_label)


def test_grid_mitigation_strategies():
    """The non-confluent cells carry the paper's prose mitigations: escrow
    for threshold counters, deferred assignment for sequences, sync for the
    rest."""
    for kind, op, strategy in [
            (InvariantKind.GREATER_THAN, OpKind.DECREMENT, Strategy.ESCROW),
            (InvariantKind.LESS_THAN, OpKind.INCREMENT, Strategy.ESCROW),
            (InvariantKind.AUTO_INCREMENT, OpKind.INSERT,
             Strategy.DEFERRED_ASSIGNMENT),
            (InvariantKind.UNIQUENESS, OpKind.ASSIGN_SPECIFIC,
             Strategy.SYNC_COORDINATION),
            (InvariantKind.CUSTOM, OpKind.DECREMENT,
             Strategy.SYNC_COORDINATION),
            (InvariantKind.LIST_POSITION, OpKind.CASCADING_DELETE,
             Strategy.SYNC_COORDINATION)]:
        v = classify(Invariant("i", kind), Op(op))
        assert v.strategy is strategy, (kind, op, v)


@pytest.mark.parametrize("kind,op,expected", [
    (InvariantKind.EQUALITY, OpKind.INSERT, True),
    (InvariantKind.EQUALITY, OpKind.DELETE, True),
    (InvariantKind.INEQUALITY, OpKind.UPDATE, True),
    (InvariantKind.UNIQUENESS, OpKind.ASSIGN_SPECIFIC, False),
    (InvariantKind.UNIQUENESS, OpKind.ASSIGN_SOME, True),
    (InvariantKind.UNIQUENESS, OpKind.DELETE, True),
    (InvariantKind.UNIQUENESS, OpKind.READ, True),
    (InvariantKind.AUTO_INCREMENT, OpKind.INSERT, False),
    (InvariantKind.FOREIGN_KEY, OpKind.INSERT, True),
    (InvariantKind.FOREIGN_KEY, OpKind.DELETE, False),
    (InvariantKind.FOREIGN_KEY, OpKind.CASCADING_DELETE, True),
    (InvariantKind.SECONDARY_INDEX, OpKind.UPDATE, True),
    (InvariantKind.MATERIALIZED_VIEW, OpKind.UPDATE, True),
    (InvariantKind.GREATER_THAN, OpKind.INCREMENT, True),
    (InvariantKind.GREATER_THAN, OpKind.DECREMENT, False),
    (InvariantKind.LESS_THAN, OpKind.DECREMENT, True),
    (InvariantKind.LESS_THAN, OpKind.INCREMENT, False),
    (InvariantKind.CONTAINS, OpKind.INSERT, True),
    (InvariantKind.LIST_POSITION, OpKind.LIST_MUTATE, False),
])
def test_pairwise_rules(kind, op, expected):
    v = classify(Invariant("i", kind), Op(op))
    assert v.coordination_free == expected, v


def test_strategies_follow_paper_prose():
    # uniqueness via some-value -> replica namespacing (§5.1)
    v = classify(Invariant("u", InvariantKind.UNIQUENESS), Op(OpKind.ASSIGN_SOME))
    assert v.strategy is Strategy.REPLICA_NAMESPACE
    # threshold decrement -> escrow (§8)
    v = classify(Invariant("g", InvariantKind.GREATER_THAN), Op(OpKind.DECREMENT))
    assert v.strategy is Strategy.ESCROW
    # auto-increment -> deferred commit-time assignment (§6.2 TPC-C)
    v = classify(Invariant("a", InvariantKind.AUTO_INCREMENT), Op(OpKind.INSERT))
    assert v.strategy is Strategy.DEFERRED_ASSIGNMENT
    # specific-value uniqueness -> synchronous coordination
    v = classify(Invariant("u", InvariantKind.UNIQUENESS), Op(OpKind.ASSIGN_SPECIFIC))
    assert v.strategy is Strategy.SYNC_COORDINATION


def test_reads_always_confluent():
    for kind in InvariantKind:
        v = classify(Invariant("i", kind), Op(OpKind.READ))
        assert v.coordination_free, (kind, v)


def test_custom_invariants_conservative():
    v = classify(Invariant("c", InvariantKind.CUSTOM), Op(OpKind.UPDATE))
    assert not v.coordination_free


# -- transaction-level ------------------------------------------------------


def test_payroll_application_analysis():
    """Paper §2: ID assignment needs coordination, department moves don't."""
    txns = payroll_transactions()
    invs = iv.payroll_invariants()
    reports = an.analyze_application(txns, invs)

    assert reports["assign_employee_id"].coordination_free          # some-value
    assert not reports["assign_employee_id_manual"].coordination_free
    assert reports["hire_into_department"].coordination_free        # FK insert
    assert reports["dissolve_department"].coordination_free         # cascading
    assert not reports["give_raise"].coordination_free              # salary<cap, incr
    assert reports["cut_salary"].coordination_free                  # decr toward floor ok


def test_transaction_conjunction():
    """One bad (op, invariant) pair poisons the whole transaction."""
    invs = (iv.unique("pk", "t.id"), iv.greater_than("pos", "t.ctr", 0.0))
    good = tx.txn("good", tx.assign_some("t.id"), tx.increment("t.ctr"))
    bad = tx.txn("bad", tx.assign_some("t.id"), tx.decrement("t.ctr"))
    assert an.analyze_transaction(good, invs).coordination_free
    rep = an.analyze_transaction(bad, invs)
    assert not rep.coordination_free
    assert Strategy.ESCROW in rep.required_strategies
    assert len(rep.blocking_pairs()) == 1


def test_target_relevance_scoping():
    """Ops on unrelated tables do not interact with an invariant."""
    invs = (iv.unique("pk", "users.id"),)
    t = tx.txn("touch_other", tx.assign_specific("orders.id"))
    rep = an.analyze_transaction(t, invs)
    assert rep.coordination_free  # orders.id doesn't touch users.id


def test_fk_watches_referenced_table():
    invs = (iv.foreign_key("fk", "employees.dept", references="departments.id"),)
    t = tx.txn("drop_dept", tx.delete("departments"))
    rep = an.analyze_transaction(t, invs)
    assert not rep.coordination_free
    t2 = tx.txn("drop_dept_cascade", tx.delete("departments", cascading=True))
    assert an.analyze_transaction(t2, invs).coordination_free


def test_summary_renders():
    invs = (iv.unique("pk", "users.id"),)
    t = tx.txn("ins", tx.assign_specific("users.id"))
    s = an.analyze_transaction(t, invs).summary()
    assert "requires coordination" in s
