"""Hypothesis property tests on the TPC-C engine's invariants:

For arbitrary interleavings of New-Order / Payment / Delivery batches,
arbitrary remote fractions, and arbitrary anti-entropy deferral, the engine
must maintain the confluent criteria continuously and ALL twelve after the
outboxes drain (the paper's global I-validity at convergence).
"""

import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.txn import tpcc
from repro.txn.engine import single_host_engine
from repro.txn.tpcc import TPCCScale, check_consistency, init_state

SCALE = TPCCScale(n_warehouses=2, districts=2, customers=8, n_items=32,
                  order_capacity=256, max_lines=15)


@pytest.fixture(scope="module")
def engine():
    return single_host_engine(SCALE)


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    plan=st.lists(st.sampled_from(["N", "P", "D", "M"]), min_size=3,
                  max_size=10),
    remote_frac=st.sampled_from([0.0, 0.3, 1.0]),
)
def test_random_interleavings_converge_valid(engine, seed, plan, remote_frac):
    """N=New-Order batch, P=Payment batch, D=Delivery, M=anti-entropy merge;
    after draining, all twelve criteria hold."""
    rng = np.random.default_rng(seed)
    state = engine.shard_state(init_state(SCALE, seed=seed % 7))
    pending = []
    ts = 0
    for op in plan:
        if op == "N":
            batch = tpcc.generate_neworder(rng, SCALE, 8,
                                           remote_frac=remote_frac, ts0=ts)
            ts += 8
            state, outbox, _ = engine.neworder_step(state, batch)
            pending.append(outbox)
        elif op == "P":
            state = engine.payment_step(
                state, tpcc.generate_payment(rng, SCALE, 8))
        elif op == "D":
            state, _ = engine.delivery_step(state)
        else:  # M: merge may happen at ANY point (Definition 3)
            for ob in pending:
                state = engine.anti_entropy(state, ob)
            pending = []
    for ob in pending:
        state = engine.anti_entropy(state, ob)
    c = check_consistency(state)
    assert all(c.values()), (plan, c)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n_batches=st.integers(2, 5))
def test_merge_order_independence(engine, seed, n_batches):
    """Outboxes may drain in any order — final stock sums agree (the merge
    is a commutative delta-join)."""
    rng = np.random.default_rng(seed)
    batches = [tpcc.generate_neworder(rng, SCALE, 8, remote_frac=0.5,
                                      ts0=i * 8) for i in range(n_batches)]

    def run(order):
        state = engine.shard_state(init_state(SCALE, seed=1))
        boxes = []
        for b in batches:
            state, ob, _ = engine.neworder_step(state, b)
            boxes.append(ob)
        for i in order:
            state = engine.anti_entropy(state, boxes[i])
        return np.asarray(jax.device_get(state.s_ytd))

    fwd = run(list(range(n_batches)))
    rev = run(list(range(n_batches))[::-1])
    np.testing.assert_allclose(fwd, rev, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), batch=st.integers(1, 24))
def test_sequential_ids_dense_for_any_batch_size(engine, seed, batch):
    """Batched increment-and-get yields dense per-district order IDs for
    arbitrary batch compositions."""
    rng = np.random.default_rng(seed)
    state = engine.shard_state(init_state(SCALE, seed=2))
    b = tpcc.generate_neworder(rng, SCALE, batch, remote_frac=0.0)
    state, _, _ = engine.neworder_step(state, b)
    s = jax.device_get(state)
    assert bool(np.array_equal(s.d_next_o_id, s.o_valid.sum(-1)))
