"""HLO analysis layer: collective parsing, replica groups, loop scaling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.roofline import (analytic_flops, analytic_hbm_bytes, build,
                                 loop_scaled_collective_bytes,
                                 trip_counts_for)
from repro.configs import registry
from repro.utils import compat
from repro.models.config import SHAPES
from repro.utils.hlo import (_parse_replica_groups, collective_stats,
                             cross_pod_collectives, shape_bytes)

HLO_SAMPLE = """
ENTRY %main (p0: f32[128]) -> f32[128] {
  %ag = f32[16,128]{1,0} all-gather(f32[128]{0} %p0), channel_id=1, replica_groups={{0,1},{2,3}}, dimensions={0}
  %ar = f32[128]{0} all-reduce(f32[128]{0} %x), channel_id=2, replica_groups=[2,2]<=[4], to_apply=%add
  %rs = f32[8]{0} reduce-scatter(f32[128]{0} %y), channel_id=3, replica_groups={{0,1,2,3}}
  ROOT %out = f32[128]{0} add(f32[128]{0} %a, f32[128]{0} %b)
}
"""


def test_collective_stats_counts_and_bytes():
    st = collective_stats(HLO_SAMPLE)
    assert st.counts["all-gather"] == 1
    assert st.counts["all-reduce"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.total_ops == 3
    # all-gather output 16*128*4 = 8192 bytes dominates its operand
    assert st.output_bytes["all-gather"] == 16 * 128 * 4
    # reduce-scatter: operand (128*4) > output (8*4)
    assert st.operand_bytes["reduce-scatter"] == 128 * 4


def test_shape_bytes_dtypes():
    assert shape_bytes("bf16", "4,4") == 32
    assert shape_bytes("f32", "10") == 40
    assert shape_bytes("pred", "8") == 8
    assert shape_bytes("s8", "100") == 100


def test_replica_groups_parsing():
    assert _parse_replica_groups(
        "x replica_groups={{0,1},{2,3}} y") == [[0, 1], [2, 3]]
    got = _parse_replica_groups("replica_groups=[2,2]<=[4]")
    assert got == [[0, 1], [2, 3]]
    got = _parse_replica_groups("replica_groups=[2,2]<=[2,2]T(1,0)")
    assert got == [[0, 2], [1, 3]]
    assert _parse_replica_groups("no groups here") is None


def test_cross_pod_detection():
    # pod size 2: {0,1} intra, {2,3} intra, [2,2]<=[4] -> {0,1},{2,3} intra
    assert cross_pod_collectives(HLO_SAMPLE, pod_size=2) == [
        {"opcode": "reduce-scatter", "group_size": 4, "pods": [0, 1]}]
    # pod size 1: everything crosses
    assert len(cross_pod_collectives(HLO_SAMPLE, pod_size=1)) == 3


def test_loop_scaling_against_unrolled():
    """Scan-of-L vs unrolled-L: loop-scaled bytes must match."""
    mesh = jax.make_mesh((1,), ("model",))
    L, D = 4, 64

    def scanned(x, w):
        def body(c, wi):
            c = jax.lax.with_sharding_constraint(
                c @ wi, jax.sharding.PartitionSpec("model"))
            return c, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    def unrolled(x, w):
        for i in range(L):
            x = jax.lax.with_sharding_constraint(
                x @ w[i], jax.sharding.PartitionSpec("model"))
        return x

    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    with compat.set_mesh(mesh):
        t1 = jax.jit(scanned).lower(x, w).compile().as_text()
        t2 = jax.jit(unrolled).lower(x, w).compile().as_text()
    b_scan = loop_scaled_collective_bytes(t1, [L])
    b_unroll = loop_scaled_collective_bytes(t2, [L])
    # 1-device mesh: likely no collectives at all; the invariant is equality
    assert b_scan == b_unroll


def test_analytic_flops_sanity():
    cfg = registry.get_config("tinyllama-1.1b")
    shape = SHAPES["train_4k"]
    model, total = analytic_flops(cfg, shape, training=True, remat=True)
    n = registry.exact_active_param_count(cfg)
    tokens = shape.global_batch * shape.seq_len
    assert model == pytest.approx(6 * n * tokens, rel=1e-6)
    assert total > model  # remat + attention overheads
    # decode: 2*N*B plus attention over the cache
    d_model, d_total = analytic_flops(cfg, SHAPES["decode_32k"],
                                      training=False)
    assert d_model == pytest.approx(2 * n * 128, rel=1e-6)
    assert d_total > d_model


def test_analytic_flops_moe_uses_active_params():
    cfg = registry.get_config("qwen3-moe-30b-a3b")
    m, _ = analytic_flops(cfg, SHAPES["train_4k"], training=True)
    n_active = registry.exact_active_param_count(cfg)
    n_total = registry.exact_param_count(cfg)
    tokens = SHAPES["train_4k"].global_batch * SHAPES["train_4k"].seq_len
    assert m == pytest.approx(6 * n_active * tokens, rel=1e-6)
    assert n_active < n_total / 5


def test_analytic_hbm_chunked_below_naive():
    cfg = registry.get_config("smollm-360m")
    naive = analytic_hbm_bytes(cfg, SHAPES["prefill_32k"], training=False,
                               chips=256, attn_impl="naive")
    chunked = analytic_hbm_bytes(cfg, SHAPES["prefill_32k"], training=False,
                                 chips=256, attn_impl="chunked")
    assert chunked < naive / 2


def test_trip_counts():
    assert trip_counts_for(registry.get_config("tinyllama-1.1b"),
                           SHAPES["train_4k"]) == [22]
    assert trip_counts_for(registry.get_config("rwkv6-3b"),
                           SHAPES["train_4k"]) == [32, 64]
    assert trip_counts_for(registry.get_config("llama-3.2-vision-11b"),
                           SHAPES["decode_32k"]) == [8, 4]


def test_roofline_build_terms_positive():
    r = build("tinyllama-1.1b", SHAPES["train_4k"], "16x16", 256,
              collective_bytes=1e9)
    assert r.t_compute > 0 and r.t_memory > 0 and r.t_collective > 0
    assert r.bottleneck in ("compute", "memory", "collective")
    assert 0 < r.useful_fraction <= 1
