"""Fused Pallas merge+audit wired into core.merge — matches the lattice join
and flags invariant violations that local checks could not see pre-merge."""

import jax.numpy as jnp
import numpy as np

from repro.core.lattice import VersionedSlots
from repro.core.merge import merge_versioned_fused


def _mk(rng, r, cap=128, width=4):
    return VersionedSlots(
        jnp.asarray(rng.random(cap) < 0.6),
        jnp.asarray(((rng.integers(0, 40, cap)) * 4 + r).astype(np.int64)),
        jnp.asarray(rng.normal(0, 2, (cap, width)).astype(np.float32)))


def test_fused_merge_matches_join():
    rng = np.random.default_rng(0)
    a, b = _mk(rng, 0), _mk(rng, 1)
    want = VersionedSlots.join(a, b)
    got, viol = merge_versioned_fused(a, b)
    np.testing.assert_array_equal(np.asarray(got.valid), np.asarray(want.valid))
    np.testing.assert_array_equal(np.asarray(got.payload),
                                  np.asarray(want.payload))
    np.testing.assert_array_equal(np.asarray(got.version),
                                  np.asarray(want.version))
    assert not bool(viol.any())  # wide-open thresholds: nothing flagged


def test_fused_merge_audits_threshold():
    """A merge can surface rows violating a threshold invariant even though
    each side was locally valid for its own writes — the audit mask is the
    detection hook (paper: global validity must hold post-merge)."""
    cap, width = 64, 2
    a = VersionedSlots(jnp.ones(cap, bool), jnp.full((cap,), 4, jnp.int64),
                       jnp.full((cap, width), 1.0, jnp.float32))
    hot = jnp.zeros((cap, width), jnp.float32).at[7].set(99.0)
    b = VersionedSlots(jnp.ones(cap, bool), jnp.full((cap,), 9, jnp.int64),
                       jnp.ones((cap, width), jnp.float32) + hot)
    merged, viol = merge_versioned_fused(a, b, lo=-10.0, hi=10.0)
    assert bool(viol[7]) and int(viol.sum()) == 1
    assert float(merged.payload[7, 0]) == 100.0  # b newer -> its row won
