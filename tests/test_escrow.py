"""EscrowCounter (core/lattice.py, paper §8 escrow method): local spends on
disjoint shares commute, overspend is rejected locally, and joins of divergent
replica states preserve the global budget invariant."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lattice import (EscrowCounter, HotSetEscrow,
                                check_lattice_laws)

R, BUDGET, FLOOR = 4, 100.0, 20.0


def _make():
    return EscrowCounter.make(R, BUDGET, floor=FLOOR)


def test_shares_partition_headroom():
    esc = _make()
    assert np.isclose(float(esc.shares.sum()), BUDGET - FLOOR)
    assert np.isclose(float(esc.remaining()), BUDGET - FLOOR)


def test_disjoint_spends_commute():
    """Replica-local spends target disjoint slots, so any execution order
    yields the same state — the I-confluence that makes escrow free."""
    ops = [(0, 5.0), (1, 7.0), (2, 19.0), (0, 3.0), (3, 20.0), (1, 1.5)]
    final = None
    for perm in ([0, 1, 2, 3, 4, 5], [5, 4, 3, 2, 1, 0], [2, 0, 5, 3, 1, 4]):
        esc = _make()
        for j in perm:
            replica, amt = ops[j]
            esc, ok = esc.try_spend(replica, amt)
            assert bool(ok)
        if final is None:
            final = esc
        assert np.allclose(np.asarray(esc.spent), np.asarray(final.spent))
        assert np.allclose(np.asarray(esc.shares), np.asarray(final.shares))


def test_overspend_rejected_and_state_unchanged():
    esc = _make()
    share = float(esc.shares[0])
    esc, ok = esc.try_spend(0, share)          # spend the whole share
    assert bool(ok)
    before = np.asarray(esc.spent).copy()
    esc, ok = esc.try_spend(0, 0.01)           # one cent over
    assert not bool(ok)
    assert np.array_equal(np.asarray(esc.spent), before)
    # other replicas' shares are untouched and still spendable
    esc, ok = esc.try_spend(1, 1.0)
    assert bool(ok)


def test_join_of_divergent_spends_preserves_budget():
    """Two replicas diverge (each spends locally), then join: the merged
    state reflects both spends exactly once and value stays >= floor."""
    base = _make()
    a, ok_a = base.try_spend(0, 10.0)
    assert bool(ok_a)
    b, ok_b = base.try_spend(1, 15.0)
    assert bool(ok_b)
    m = EscrowCounter.join(a, b)
    assert np.isclose(float(m.spent.sum()), 25.0)
    value = BUDGET - float(m.spent.sum())
    assert value >= FLOOR
    # join is idempotent under repeated anti-entropy
    m2 = EscrowCounter.join(m, a)
    assert np.isclose(float(m2.spent.sum()), 25.0)


def test_worst_case_total_spend_never_breaks_floor():
    """Even if every replica exhausts its share concurrently, the global
    value cannot drop below the floor (sum(shares) == budget - floor)."""
    esc = _make()
    for r in range(R):
        esc, ok = esc.try_spend(r, float(esc.shares[r]))
        assert bool(ok)
    assert np.isclose(float(esc.remaining()), 0.0)
    assert BUDGET - float(esc.spent.sum()) >= FLOOR - 1e-5


def test_refresh_rebalances_without_changing_value():
    esc = _make()
    esc, _ = esc.try_spend(0, float(esc.shares[0]))   # replica 0 exhausted
    remaining_before = float(esc.remaining())
    esc = esc.refresh()
    assert np.isclose(float(esc.remaining()), remaining_before)
    # after the amortized coordination point, replica 0 can spend again
    esc, ok = esc.try_spend(0, 1.0)
    assert bool(ok)


def test_lattice_laws_on_samples():
    base = _make()
    a, _ = base.try_spend(0, 4.0)
    b, _ = base.try_spend(2, 9.0)
    c, _ = a.try_spend(3, 2.5)
    check_lattice_laws(EscrowCounter.join, [base, a, b, c])


def test_join_of_diverged_refresh_is_conservative():
    """The min(shares) headroom loss, pinned as INTENTIONAL (see
    EscrowCounter.join): when one side refreshed (fresh, larger shares) and
    the other did not, the join keeps the smaller allocation — merged
    headroom UNDER-states the truth (capacity lost until the next refresh),
    but per-slot admission capacity never exceeds either input's, which is
    the safety direction the §8 argument needs (a max-join would let the
    same re-granted headroom be spent twice)."""
    base = _make()
    a, ok = base.try_spend(0, float(base.shares[0]))   # replica 0 exhausted
    assert bool(ok)
    refreshed = a.refresh()        # rebalanced: replica 0 re-granted
    m = EscrowCounter.join(refreshed, a)

    # conservative: per-slot headroom of the join never exceeds either side
    for side in (refreshed, a):
        assert np.all(np.asarray(m.shares - m.spent)
                      <= np.asarray(side.shares - side.spent) + 1e-6)
    # the loss is real (strictly less headroom than the refreshed side saw):
    # the diverged stale view pins replica 0 back to its pre-refresh share
    assert float(m.remaining()) < float(refreshed.remaining())
    # and safety holds: total spendable capacity still respects the floor
    worst_spend = float(np.maximum(
        0.0, np.asarray(m.shares - m.spent)).sum()
        + np.asarray(m.spent).sum())
    assert BUDGET - worst_spend >= FLOOR - 1e-5


# -- sparse hot-set variant (core/lattice.py HotSetEscrow) -------------------


def test_hot_set_escrow_lattice_laws_and_lookup():
    """Same-epoch HotSetEscrow joins satisfy the lattice laws; the sorted
    key table resolves hot membership; cold keys cannot spend."""
    keys = np.asarray([3, 7, 11, 42], np.int32)
    budgets = np.asarray([10, 20, 30, 40], np.int32)
    base = HotSetEscrow.make(3, keys, budgets)
    assert np.array_equal(np.asarray(base.shares.sum(0)), budgets)
    a, ok = base.try_spend(0, 7, 5)
    assert bool(ok)
    b, ok = base.try_spend(2, 42, 13)
    assert bool(ok)
    c, ok = a.try_spend(1, 11, 10)
    assert bool(ok)
    check_lattice_laws(HotSetEscrow.join, [base, a, b, c])
    # overspend of one replica's slot rejected, state unchanged
    d, ok = base.try_spend(0, 3, 99)
    assert not bool(ok)
    assert np.array_equal(np.asarray(d.spent), np.asarray(base.spent))
    # cold key: rejected (the owner route handles it, not the table)
    _, ok = base.try_spend(0, 5, 1)
    assert not bool(ok)
    # refresh re-partitions new budgets exactly
    r = a.refresh(jnp.asarray([9, 9, 9, 9], jnp.int32))
    assert int(np.asarray(r.spent).sum()) == 0
    assert np.array_equal(np.asarray(r.shares.sum(0)), [9, 9, 9, 9])
