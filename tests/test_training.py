"""Training substrate: optimizer, coord modes, pipeline, checkpoint, restart."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.planner import CoordClass
from repro.data.pipeline import DataConfig, Pipeline, ShardCursor
from repro.models.sharding import Rules
from repro.optim import adamw, coord
from repro.runtime import train as train_rt

CFG = registry.get_config("smollm-360m").reduced()


def _mesh1():
    return jax.make_mesh((1, 1, 1), ("pod", "data", "model"))


def _setup(mode="sync", **kw):
    rules = Rules(batch=("pod", "data"))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    batch_specs = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype)
        for k, v in registry.make_train_batch(
            jax.random.PRNGKey(0), CFG, 4, 16).items()}
    cc = coord.CoordConfig(mode=mode, **kw)
    return coord.build(CFG, rules, _mesh1(), cc, opt_cfg,
                       lambda c, r: registry.make_loss_fn(c, r, remat=False),
                       batch_specs)


def test_adamw_reduces_loss():
    setup = _setup("sync")
    state = setup.init_fn(jax.random.PRNGKey(0))
    batch = registry.make_train_batch(jax.random.PRNGKey(1), CFG, 4, 16)
    losses = []
    for i in range(12):
        state = setup.step_fn(state, batch)
        losses.append(float(state.loss_slots.sum()) - sum(losses))
    assert losses[-1] < losses[0], losses  # memorizes the fixed batch


def test_lr_schedule():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
    assert float(adamw.lr_at(cfg, jnp.asarray(0))) == 0.0
    assert float(adamw.lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(adamw.lr_at(cfg, jnp.asarray(110))) == pytest.approx(0.1)


def test_escrow_clip_bounds_global_norm():
    """R local clips at tau/sqrt(R) bound the global norm by tau."""
    cfg = adamw.AdamWConfig(clip_norm=1.0, clip_mode="escrow", num_replicas=4)
    rng = np.random.default_rng(0)
    shards = [jax.tree.map(jnp.asarray, {"w": rng.normal(0, 5, (16,))})
              for _ in range(4)]
    clipped = [adamw.clip_grads(s, cfg)[0] for s in shards]
    total = sum(float(adamw.global_norm(c)) ** 2 for c in clipped)
    assert np.sqrt(total) <= 1.0 + 1e-5


def test_plan_validation_rejects_exact_clip_in_deferred_mode():
    tc = train_rt.TrainConfig(
        coord=coord.CoordConfig(mode="local_sgd"),
        opt=adamw.AdamWConfig(clip_mode="exact"))
    with pytest.raises(ValueError, match="coordination plan violation"):
        train_rt.validate_plan(tc)
    plan = train_rt.coordination_plan(train_rt.TrainConfig())
    assert plan.entry("grads").coord_class is CoordClass.FREE


def test_pipeline_determinism_and_unique_ids():
    dc = DataConfig(vocab=CFG.vocab, seq_len=16, global_batch=8, seed=3,
                    n_shards=4)
    p1, p2 = Pipeline(dc, CFG), Pipeline(dc, CFG)
    b1, b2 = p1.next_batch(), p2.next_batch()
    assert jnp.array_equal(b1["tokens"], b2["tokens"])  # deterministic
    ids = p1.sample_ids_seen()
    assert len(ids) == 8  # all unique (replica-namespaced)
    p1.next_batch()
    assert len(p1.sample_ids_seen()) == 16


def test_cursor_max_join():
    a = ShardCursor(0, 2, cursor=5)
    b = ShardCursor(0, 2, cursor=9)
    assert ShardCursor.join(a, b).cursor == 9


def test_train_run_and_checkpoint_restart():
    mesh = _mesh1()
    rules = Rules(batch=("pod", "data"))
    with tempfile.TemporaryDirectory() as d:
        tc = train_rt.TrainConfig(steps=6, log_every=3, ckpt_every=3,
                                  ckpt_dir=d, seq_len=16, global_batch=4,
                                  remat=False,
                                  opt=adamw.AdamWConfig(warmup_steps=1,
                                                        total_steps=10))
        state, summary = train_rt.run(CFG, mesh, rules, tc)
        assert summary["step"] == 6
        assert os.path.exists(os.path.join(d, "SEQUENCE"))

        # restart from checkpoint: step resumes past the manifest step
        tc2 = train_rt.TrainConfig(steps=8, log_every=4, ckpt_every=0,
                                   ckpt_dir=d, seq_len=16, global_batch=4,
                                   remat=False,
                                   opt=adamw.AdamWConfig(warmup_steps=1,
                                                         total_steps=10))
        state2, summary2 = train_rt.run(CFG, mesh, rules, tc2, restore_from=d)
        assert summary2["step"] == 8
