"""Checkpoint lattice manifests, concurrent writers, elastic restore."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck


def _state():
    return {"params": {"w": jnp.arange(8.0), "b": jnp.ones((2, 3))},
            "step": jnp.asarray(5)}


def test_save_restore_roundtrip():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        man = ck.save(d, s, step=5)
        assert ck.is_complete(man, s)
        out = ck.restore(d, man, jax.eval_shape(lambda: s))
        for a, b in zip(jax.tree_util.tree_leaves(s),
                        jax.tree_util.tree_leaves(out)):
            assert jnp.array_equal(a, b)


def test_concurrent_writers_merge_to_complete_manifest():
    """Two writers each save half the tree; manifests join (or-join on the
    shard set) into a complete checkpoint — no write barrier needed."""
    s = _state()
    names = [n for n, _ in ck._flatten_with_names(s)]
    half1, half2 = set(names[:2]), set(names[2:])
    with tempfile.TemporaryDirectory() as d:
        m1 = ck.save(d, s, step=7, writer="w1", partial=half1)
        m2 = ck.save(d, s, step=7, writer="w2", partial=half2)
        m2 = dataclasses.replace(m2, temp_id=m1.temp_id)  # same logical ckpt
        assert not ck.is_complete(m1, s)      # failure-detectable partials
        assert not ck.is_complete(m2, s)
        merged = ck.merge_manifests([m1, m2])
        assert ck.is_complete(merged, s)
        out = ck.restore(d, merged, jax.eval_shape(lambda: s))
        assert jnp.array_equal(out["params"]["w"], s["params"]["w"])


def test_manifest_join_laws():
    a = ck.Manifest(step=3, temp_id="t", shards={"x": "f1"},
                    writer_meta={"w1": {}})
    b = ck.Manifest(step=5, temp_id="t", shards={"y": "f2"},
                    writer_meta={"w2": {}})
    ab = ck.Manifest.join(a, b)
    ba = ck.Manifest.join(b, a)
    assert ab.step == ba.step == 5
    assert ab.shards == ba.shards == {"x": "f1", "y": "f2"}
    assert ck.Manifest.join(ab, ab).shards == ab.shards  # idempotent


def test_sequential_assignment_is_dense():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        ids = []
        for step in (1, 2, 3):
            man = ck.save(d, s, step=step)
            man = ck.assign_sequential(d, man)
            ids.append(man.seq_id)
        assert ids == [0, 1, 2]  # dense, no gaps (single assigner)
        latest = ck.latest_manifest(d)
        assert latest.seq_id == 2


def test_elastic_restore_new_sharding():
    """Restore under a different sharding (1 device here, but exercised via
    explicit NamedSharding)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        man = ck.save(d, s, step=1)
        shardings = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), jax.eval_shape(lambda: s))
        out = ck.restore(d, man, jax.eval_shape(lambda: s), shardings)
        assert jnp.array_equal(out["params"]["b"], s["params"]["b"])


def test_mid_commit_crash_leaves_previous_committed(monkeypatch):
    """Fault injection: the writer dies between bumping SEQUENCE and
    publishing the committed manifest. With atomic (temp + os.replace)
    writes the directory holds either the old commit or the new one —
    ``latest_manifest`` must return the previous committed checkpoint,
    never a parse error or a truncated manifest."""
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        man0 = ck.save(d, s, step=1)
        committed0 = ck.assign_sequential(d, man0)        # ckpt-000000
        man1 = ck.save(d, s, step=2)
        real_replace = os.replace

        def crash_on_manifest(src, dst):
            if dst.endswith(".manifest.json"):
                raise RuntimeError("killed mid-commit")   # power cut
            return real_replace(src, dst)

        monkeypatch.setattr(ck.os, "replace", crash_on_manifest)
        with pytest.raises(RuntimeError):
            ck.assign_sequential(d, man1)
        monkeypatch.setattr(ck.os, "replace", real_replace)
        latest = ck.latest_manifest(d)
        assert latest is not None
        assert latest.seq_id == committed0.seq_id == 0
        assert latest.step == 1
        # the torn commit left no committed manifest at all (only tmp
        # debris) — a fresh assigner can still commit cleanly
        man2 = ck.assign_sequential(d, ck.save(d, s, step=3))
        assert ck.latest_manifest(d).seq_id == man2.seq_id


def test_truncated_manifests_and_sequence_are_skipped():
    """Legacy (pre-atomic-write) corruption on disk: a truncated committed
    manifest is skipped in favor of the previous committed one, and a
    garbage SEQUENCE is re-derived from the committed IDs."""
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        man0 = ck.assign_sequential(d, ck.save(d, s, step=1))  # ckpt-000000
        good = ck.save(d, s, step=2)
        torn = os.path.join(d, "ckpt-000001.manifest.json")
        with open(torn, "w") as f:
            f.write(good.to_json()[:25])          # half-written JSON
        latest = ck.latest_manifest(d)
        assert latest.seq_id == man0.seq_id == 0  # fell back, no crash
        with open(os.path.join(d, "SEQUENCE"), "w") as f:
            f.write("1x")                         # truncated counter
        man2 = ck.assign_sequential(d, ck.save(d, s, step=3))
        assert man2.seq_id == 2                   # max committed id + 1
        assert ck.latest_manifest(d).seq_id == 2


def test_newest_temp_is_by_writer_time_not_filename():
    """Regression: temp ids are random uuid hex, so lexicographic filename
    order picks an arbitrary generation. Two temp generations written out
    of lexical order must resolve to the newest writer_meta timestamp."""
    def _write_temp(d, temp_id, t, step):
        man = ck.Manifest(step=step, temp_id=temp_id,
                          shards={"x": f"{temp_id}-w0.npz"},
                          writer_meta={"w0": {"time": t, "n_shards": 1}})
        with open(os.path.join(d, f"{temp_id}-w0.manifest.json"), "w") as f:
            f.write(man.to_json())

    with tempfile.TemporaryDirectory() as d:
        _write_temp(d, "zz-old-gen", t=100.0, step=1)   # sorts LAST
        _write_temp(d, "aa-new-gen", t=200.0, step=2)   # sorts first
        latest = ck.latest_manifest(d)
        assert latest.temp_id == "aa-new-gen"
        assert latest.step == 2


def test_digit_prefixed_temp_id_does_not_shadow_committed():
    """Regression: temp ids are random hex, so ~6% begin with six digits —
    a temp manifest (seq_id=None) must never sort above a committed
    ckpt-NNNNNN manifest in latest_manifest."""
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        man = ck.save(d, s, step=1)
        committed = ck.assign_sequential(d, man)  # ckpt-000000
        # adversarial temp manifest: six leading digits, sorts after
        shadow = dataclasses.replace(man, temp_id="ckpt-999999aaaaaa")
        with open(os.path.join(d, "ckpt-999999aaaaaa-w0.manifest.json"),
                  "w") as f:
            f.write(shadow.to_json())
        latest = ck.latest_manifest(d)
        assert latest.seq_id == committed.seq_id == 0
