"""Checkpoint lattice manifests, concurrent writers, elastic restore."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck


def _state():
    return {"params": {"w": jnp.arange(8.0), "b": jnp.ones((2, 3))},
            "step": jnp.asarray(5)}


def test_save_restore_roundtrip():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        man = ck.save(d, s, step=5)
        assert ck.is_complete(man, s)
        out = ck.restore(d, man, jax.eval_shape(lambda: s))
        for a, b in zip(jax.tree_util.tree_leaves(s),
                        jax.tree_util.tree_leaves(out)):
            assert jnp.array_equal(a, b)


def test_concurrent_writers_merge_to_complete_manifest():
    """Two writers each save half the tree; manifests join (or-join on the
    shard set) into a complete checkpoint — no write barrier needed."""
    s = _state()
    names = [n for n, _ in ck._flatten_with_names(s)]
    half1, half2 = set(names[:2]), set(names[2:])
    with tempfile.TemporaryDirectory() as d:
        m1 = ck.save(d, s, step=7, writer="w1", partial=half1)
        m2 = ck.save(d, s, step=7, writer="w2", partial=half2)
        m2 = dataclasses.replace(m2, temp_id=m1.temp_id)  # same logical ckpt
        assert not ck.is_complete(m1, s)      # failure-detectable partials
        assert not ck.is_complete(m2, s)
        merged = ck.merge_manifests([m1, m2])
        assert ck.is_complete(merged, s)
        out = ck.restore(d, merged, jax.eval_shape(lambda: s))
        assert jnp.array_equal(out["params"]["w"], s["params"]["w"])


def test_manifest_join_laws():
    a = ck.Manifest(step=3, temp_id="t", shards={"x": "f1"},
                    writer_meta={"w1": {}})
    b = ck.Manifest(step=5, temp_id="t", shards={"y": "f2"},
                    writer_meta={"w2": {}})
    ab = ck.Manifest.join(a, b)
    ba = ck.Manifest.join(b, a)
    assert ab.step == ba.step == 5
    assert ab.shards == ba.shards == {"x": "f1", "y": "f2"}
    assert ck.Manifest.join(ab, ab).shards == ab.shards  # idempotent


def test_sequential_assignment_is_dense():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        ids = []
        for step in (1, 2, 3):
            man = ck.save(d, s, step=step)
            man = ck.assign_sequential(d, man)
            ids.append(man.seq_id)
        assert ids == [0, 1, 2]  # dense, no gaps (single assigner)
        latest = ck.latest_manifest(d)
        assert latest.seq_id == 2


def test_elastic_restore_new_sharding():
    """Restore under a different sharding (1 device here, but exercised via
    explicit NamedSharding)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        man = ck.save(d, s, step=1)
        shardings = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), jax.eval_shape(lambda: s))
        out = ck.restore(d, man, jax.eval_shape(lambda: s), shardings)
        assert jnp.array_equal(out["params"]["b"], s["params"]["b"])


def test_digit_prefixed_temp_id_does_not_shadow_committed():
    """Regression: temp ids are random hex, so ~6% begin with six digits —
    a temp manifest (seq_id=None) must never sort above a committed
    ckpt-NNNNNN manifest in latest_manifest."""
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        man = ck.save(d, s, step=1)
        committed = ck.assign_sequential(d, man)  # ckpt-000000
        # adversarial temp manifest: six leading digits, sorts after
        shadow = dataclasses.replace(man, temp_id="ckpt-999999aaaaaa")
        with open(os.path.join(d, "ckpt-999999aaaaaa-w0.manifest.json"),
                  "w") as f:
            f.write(shadow.to_json())
        latest = ck.latest_manifest(d)
        assert latest.seq_id == committed.seq_id == 0
