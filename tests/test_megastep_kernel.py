"""One-kernel transaction megastep (admission + effects + RAMP stamps).

Core level: for ARBITRARY megastep problems — duplicate cells within one
transaction, invalid lines, zero-headroom cells, sentinel cold-line cells,
remote/local line mixes — four implementations must be BIT-identical:

  * the definitional oracle (kernels/ref.py ``txn_megastep_ref``: scan-path
    admission + the ``[B, B]`` rank matrix + plain scatter-adds),
  * the Pallas kernel itself in interpret mode (the TPU code path executed
    on CPU — the same contract as escrow_admit / ramp_read),
  * the vectorized CPU lowering (``escrow_admit`` + the sort-based
    ``megastep_effect_products``),
  * whatever the public ``ops.txn_megastep`` dispatcher picks.

Transaction level: ``effects="fused"`` through the public New-Order entry
points (dense and sparse escrow layouts) lands bit-identical state / spent /
outbox / totals / committed as ``effects="scan"`` on the same batch, for
every admission mode, in plentiful AND starved stock regimes (aborts
present).

Engine level: ``Engine(effects="fused")`` closed loops land on bit-identical
final state / escrow counters / stats as ``effects="scan"`` across both
layouts and the fused / dispatch / legacy drivers, and the fused final
states audit clean (strict stock, conservation).

Plus the measured admission cut-over (ROADMAP item 2): the one-shot backend
autotune memoizes per (backend, batch shape), and switching it off restores
the documented constant threshold.

The problem generator is shared between a deterministic seeded sweep
(always runs) and a hypothesis-driven search (runs where hypothesis is
installed — CI installs it via the ``test`` extra).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: deterministic sweep only
    HAVE_HYPOTHESIS = False

from repro.kernels import ref
from repro.kernels.escrow_admit import contention_gate, residual_order
from repro.kernels.ops import escrow_admit, txn_megastep
from repro.kernels.txn_megastep import (MegastepOut, megastep_effect_products,
                                        txn_megastep_kernel)
from repro.txn import tpcc
from repro.txn.audit import assert_audit
from repro.txn.drivers import run_escrow_loop
from repro.txn.engine import single_host_engine
from repro.txn.tpcc import (TPCCScale, init_state, make_escrow_shares,
                            select_hot_cells)


# ---------------------------------------------------------------------------
# Core level: kernel == CPU lowering == dispatcher == oracle
# ---------------------------------------------------------------------------


def _mega_problem(seed: int, B: int = 16, L: int = 6, A: int = 48,
                  n_keys: int = 12, n_cells: int = 40, lo: int = 0,
                  hi: int = 40, dup_heavy: bool = False):
    """A random megastep problem: an admission problem (same shape space as
    the escrow_admit tests) plus district keys, local stock cells, a
    local/remote line split, RAMP timestamps and a price row."""
    rng = np.random.default_rng(seed)
    avail0 = jnp.asarray(rng.integers(lo, hi + 1, A), jnp.int32)
    cells = max(2, A // 4) if dup_heavy else A
    slot = jnp.asarray(rng.integers(0, cells, (B, L)), jnp.int32)
    qty = jnp.asarray(rng.integers(1, 11, (B, L)), jnp.int32)
    lv = jnp.asarray(rng.random((B, L)) < 0.85)
    key = jnp.asarray(rng.integers(0, n_keys, B), jnp.int32)
    loc = jnp.asarray(rng.random((B, L)) < 0.7) & lv
    cell = jnp.where(
        loc, jnp.asarray(rng.integers(0, n_cells, (B, L)), jnp.int32), 0)
    rem = jnp.asarray(rng.random((B, L)) < 0.3) & lv
    ts = jnp.asarray(rng.integers(0, 1 << 20, B), jnp.int32)
    price = jnp.asarray(rng.integers(1, 100, (B, L)), jnp.float32)
    return (avail0, slot, qty, lv, key, cell, loc, rem, ts, price), dict(
        n_keys=n_keys, n_cells=n_cells)


def _assert_mega_equal(args, kw):
    """All four implementations against the oracle, field by field."""
    avail0, slot, qty, lv = args[:4]
    ref_out = MegastepOut(*ref.txn_megastep_ref(*args, **kw))

    fast, _, _ = contention_gate(avail0, slot, qty, lv)
    res_idx, n_res = residual_order(fast)
    k_out = txn_megastep_kernel(avail0, slot, qty, lv, fast, res_idx, n_res,
                                *args[4:], **kw, interpret=True)

    c, a = escrow_admit(avail0, slot, qty, lv)
    low_out = MegastepOut(c, a, *megastep_effect_products(
        c, qty, lv, *args[4:], **kw))

    ops_out = txn_megastep(*args, **kw)

    for tag, got in (("kernel", k_out), ("lowering", low_out),
                     ("ops", ops_out)):
        for name, x, y in zip(MegastepOut._fields, ref_out, got):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y), err_msg=f"{tag}: {name}")
    return ref_out


def test_megastep_equivalence_seeded_sweep():
    """Deterministic sweep across contention levels — scarce headroom (big
    residual sets exercise the in-kernel FCFS walk), plump headroom (pure
    fast path, in-kernel settle), duplicate-heavy rows, and bigger mixed
    problems."""
    for seed in range(20):
        kind = seed % 4
        if kind == 0:      # scarce: almost everything residual
            args, kw = _mega_problem(seed, hi=12)
        elif kind == 1:    # plump: almost everything fast
            args, kw = _mega_problem(seed, lo=300, hi=500)
        elif kind == 2:    # duplicate-heavy rows on a small cell domain
            args, kw = _mega_problem(seed, dup_heavy=True, hi=50)
        else:              # mixed, bigger batch
            args, kw = _mega_problem(seed, B=32, L=8, A=80, n_keys=6,
                                     n_cells=24, hi=60)
        _assert_mega_equal(args, kw)


def test_megastep_rank_and_counter_semantics():
    """The increment-and-get contract: rank counts committed EARLIER
    same-key transactions (stored for aborted rows too, like the scan
    path's rank matrix), aborted rows never advance the district counter,
    and the stock slabs only see admitted local lines."""
    avail0 = jnp.asarray([10, 0, 1 << 30], jnp.int32)
    #          txn: fits | zero-headroom abort | fits | sentinel ride
    slot = jnp.asarray([[0], [1], [0], [2]], jnp.int32)
    qty = jnp.asarray([[4], [1], [5], [9]], jnp.int32)
    lv = jnp.ones((4, 1), jnp.bool_)
    key = jnp.asarray([0, 0, 0, 1], jnp.int32)           # 3 share a district
    loc = jnp.asarray([[True], [True], [False], [True]])
    cell = jnp.where(loc, jnp.asarray([[2], [2], [0], [3]], jnp.int32), 0)
    rem = jnp.asarray([[False], [True], [False], [True]])
    ts = jnp.asarray([7, 7, 7, 9], jnp.int32)
    price = jnp.full((4, 1), 2.0, jnp.float32)
    out = _assert_mega_equal(
        (avail0, slot, qty, lv, key, cell, loc, rem, ts, price),
        dict(n_keys=2, n_cells=4))
    assert np.asarray(out.committed).tolist() == [True, False, True, True]
    # txn 1 aborts but still reads rank 1 (one committed predecessor on key
    # 0); txn 2 also gets rank 1 — the abort did not advance the counter
    assert np.asarray(out.rank).tolist() == [0, 1, 1, 0]
    assert np.asarray(out.d_count).tolist() == [2, 1]
    # slabs: txn 0 (local, 4 units) and txn 3 (local remote-sourced, 9)
    # land; txn 1's abort and txn 2's non-local line do not
    assert np.asarray(out.stock_dec).tolist() == [0, 0, 4, 9]
    assert np.asarray(out.stock_cnt).tolist() == [0, 0, 1, 1]
    assert np.asarray(out.stock_rcnt).tolist() == [0, 0, 0, 1]
    assert np.asarray(out.amount)[:, 0].tolist() == [8.0, 2.0, 10.0, 18.0]
    assert np.asarray(out.ol_ts)[:, 0].tolist() == [7, 7, 7, 9]


def test_megastep_invalid_lines_are_inert():
    """Invalid lines neither reserve nor stamp: ol_ts carries the -1
    sentinel, amount is 0, and the slabs ignore them even when their cell
    ids alias live cells."""
    args, kw = _mega_problem(3, B=12, L=5, hi=30)
    lv = args[3].at[:, 2].set(False)                 # kill a whole column
    loc = args[6] & lv
    args = args[:3] + (lv, args[4], args[5], loc) + args[7:]
    out = _assert_mega_equal(args, kw)
    assert np.asarray(out.ol_ts)[:, 2].tolist() == [-1] * 12
    assert np.asarray(out.amount)[:, 2].tolist() == [0.0] * 12


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 100_000),
           B=st.integers(1, 20), L=st.integers(1, 6),
           A=st.integers(2, 48), n_keys=st.integers(1, 10),
           n_cells=st.integers(1, 32),
           hi=st.sampled_from([5, 20, 60, 400]), dup=st.booleans())
    def test_megastep_equivalence_hypothesis(seed, B, L, A, n_keys, n_cells,
                                             hi, dup):
        """Hypothesis search: kernel == lowering == dispatcher == oracle on
        arbitrary interleavings of duplicate / invalid / zero-headroom /
        contended / remote demand."""
        _assert_mega_equal(*_mega_problem(seed, B=B, L=L, A=A,
                                          n_keys=n_keys, n_cells=n_cells,
                                          hi=hi, dup_heavy=dup))


# ---------------------------------------------------------------------------
# Transaction level: effects="fused" == effects="scan" at the public entries
# ---------------------------------------------------------------------------


TXN_SCALE = TPCCScale(n_warehouses=2, districts=4, customers=16,
                      n_items=64, order_capacity=512, max_lines=8)


def _assert_txn_outputs_equal(a, b, tag):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{tag}: leaf {i}")


@pytest.mark.parametrize("stock", ["plentiful", "starved"])
def test_fused_entry_dense_bitexact_with_scan(stock):
    """apply_neworder_escrow(effects="fused") vs "scan" on the identical
    batch: full state, spent delta, outbox, totals, committed — every
    admission mode, with and without aborts."""
    rng = np.random.default_rng(0)
    B, W = 48, TXN_SCALE.n_warehouses
    batch = tpcc.generate_neworder(rng, TXN_SCALE, B, remote_frac=0.2,
                                   item_skew=1.0)
    state = init_state(TXN_SCALE)
    if stock == "plentiful":
        state = state._replace(s_quantity=state.s_quantity * 50)
    shares = make_escrow_shares(state.s_quantity, 2)[0]
    spent0 = jnp.zeros_like(shares)
    base = jax.jit(lambda st: tpcc.apply_neworder_escrow(
        st, shares, spent0, batch, TXN_SCALE, w_lo=0, w_hi=W,
        admission="scan", effects="scan"))(state)
    committed = np.asarray(base[4])
    if stock == "starved":
        assert not committed.all()       # the regime actually aborts
    for adm in ("scan", "kernel"):
        fused = jax.jit(lambda st, adm=adm: tpcc.apply_neworder_escrow(
            st, shares, spent0, batch, TXN_SCALE, w_lo=0, w_hi=W,
            admission=adm, effects="fused"))(state)
        _assert_txn_outputs_equal(base, fused, f"dense/{stock}/adm={adm}")


@pytest.mark.parametrize("stock", ["plentiful", "starved"])
def test_fused_entry_sparse_bitexact_with_scan(stock):
    """The same contract over the two-tier layout: hot shares + local cold
    stock + the cold-line sentinel all flow through the one fused
    admission domain."""
    rng = np.random.default_rng(1)
    B, W = 48, TXN_SCALE.n_warehouses
    batch = tpcc.generate_neworder(rng, TXN_SCALE, B, remote_frac=0.3,
                                   item_skew=1.2)
    state = init_state(TXN_SCALE)
    if stock == "plentiful":
        state = state._replace(s_quantity=state.s_quantity * 50)
    hot_keys = jnp.asarray(select_hot_cells(TXN_SCALE, 8))
    headroom = state.s_quantity.reshape(-1)[hot_keys]
    base = jax.jit(lambda st: tpcc.apply_neworder_escrow_sparse(
        st, hot_keys, headroom, jnp.zeros_like(headroom), batch, TXN_SCALE,
        w_lo=0, w_hi=W, admission="scan", effects="scan"))(state)
    if stock == "starved":
        assert not np.asarray(base[4]).all()
    for adm in ("scan", "kernel"):
        fused = jax.jit(
            lambda st, adm=adm: tpcc.apply_neworder_escrow_sparse(
                st, hot_keys, headroom, jnp.zeros_like(headroom), batch,
                TXN_SCALE, w_lo=0, w_hi=W, admission=adm,
                effects="fused"))(state)
        _assert_txn_outputs_equal(base, fused, f"sparse/{stock}/adm={adm}")


# ---------------------------------------------------------------------------
# Engine level
# ---------------------------------------------------------------------------


SCALE = TPCCScale(n_warehouses=2, districts=2, customers=8, n_items=32,
                  order_capacity=256, max_lines=15)


def _tree_equal(a, b):
    eq = jax.tree.map(lambda x, y: bool((x == y).all()), a, b)
    return [f for f, ok in zip(a._fields, eq) if not ok]


@pytest.mark.parametrize("layout", ["sparse", "dense"])
@pytest.mark.parametrize("driver", ["fused", "dispatch", "legacy"])
def test_engine_fused_effects_bitexact_with_scan(layout, driver):
    """The engine-level anchor: effects="fused" and effects="scan" land on
    bit-identical final state, escrow counters, and stats on the identical
    adversarial stream (hot/cold/remote mixes, skewed demand, aborts
    present), for both layouts and all three drivers — and the fused final
    state audits clean under the strict-stock conditions."""
    kw = dict(batch_per_shard=8, n_batches=6, remote_frac=0.3,
              merge_every=2, refresh_every=2, seed=5, mix=False,
              fused=(driver == "fused"), legacy=(driver == "legacy"),
              item_skew=1.1)
    finals = {}
    q0 = None
    for eff in ("scan", "fused"):
        eng = single_host_engine(SCALE, stock_invariant="strict",
                                 escrow_layout=layout, hot_items=4,
                                 admission="kernel", effects=eff)
        s = eng.shard_state(init_state(SCALE))
        q0 = s.s_quantity.copy()
        finals[eff] = run_escrow_loop(eng, s, **kw)
    s1, e1, m1 = finals["scan"]
    s2, e2, m2 = finals["fused"]
    assert _tree_equal(s1, s2) == []
    assert _tree_equal(e1, e2) == []
    assert (m1.neworders, m1.aborts, m1.cold_rejects) == \
        (m2.neworders, m2.aborts, m2.cold_rejects)
    assert m1.aborts > 0     # adversarial: the FCFS residue actually fired
    assert_audit(s2, escrow=e2, initial_stock=q0, strict_stock=True)


def test_engine_effects_knob_validation():
    assert tpcc.resolve_effects("fused") == "fused"
    assert tpcc.resolve_effects("scan") == "scan"
    with pytest.raises(ValueError, match="unknown effects"):
        tpcc.resolve_effects("warp")
    with pytest.raises(ValueError, match="unknown effects"):
        single_host_engine(SCALE, stock_invariant="strict", effects="warp")


# ---------------------------------------------------------------------------
# The measured admission cut-over (satellite)
# ---------------------------------------------------------------------------


def test_autotune_cutover_memoizes():
    """The one-shot backend probe: first call measures (a tiny shape keeps
    it cheap), the winner lands in the process cache, repeat calls are pure
    lookups, and the decision is one of the two real strategies."""
    key = (jax.default_backend(), 8, 3)
    saved = dict(tpcc._CUTOVER_CACHE)
    try:
        tpcc._CUTOVER_CACHE.clear()
        m1 = tpcc.resolve_admission_cutover(8, 3, cells=64, trials=1)
        assert key in tpcc._CUTOVER_CACHE
        assert m1 in ("scan", "kernel")
        tpcc._CUTOVER_CACHE[key] = "scan"     # prove repeat calls hit cache
        assert tpcc.resolve_admission_cutover(8, 3, cells=64) == "scan"
    finally:
        tpcc._CUTOVER_CACHE.clear()
        tpcc._CUTOVER_CACHE.update(saved)


def test_resolve_admission_fallback_without_autotune():
    """``ADMISSION_AUTOTUNE = False`` (and the no-line-width call shape)
    restores the documented constant threshold exactly."""
    saved = tpcc.ADMISSION_AUTOTUNE
    try:
        tpcc.ADMISSION_AUTOTUNE = False
        t = tpcc.AUTO_KERNEL_MIN_BATCH
        assert tpcc.resolve_admission("auto", t, 15) == "kernel"
        assert tpcc.resolve_admission("auto", t - 1, 15) == "scan"
    finally:
        tpcc.ADMISSION_AUTOTUNE = saved
    # without a line width "auto" cannot shape a probe: constant fallback
    assert tpcc.resolve_admission("auto", t) == "kernel"
    assert tpcc.resolve_admission("auto", t - 1) == "scan"
    # explicit modes bypass the autotune entirely
    assert tpcc.resolve_admission("scan", 4096, 15) == "scan"
    assert tpcc.resolve_admission("kernel", 1, 15) == "kernel"
