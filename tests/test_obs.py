"""Observability-plane tests (repro/obs).

The three claims the plane stands on:

* the metrics lattices obey the lattice laws (Definition 3) — property-tested
  with hypothesis, including the histogram-of-union law the
  ``HistogramLattice`` docstring promises;
* metrics are WRITE-ONLY: a metrics-on closed loop produces bit-identical
  TPCC state to metrics-off, in both the merge and the escrow regime, and
  the recorded totals cross-check against the run's MixStats;
* the coordination ledger holds hot phases to the zero-collective budget.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lattice as lat
from repro.obs import ObsSession, metrics as obsm
from repro.obs.ledger import CoordinationLedger, build_ledger
from repro.obs.trace import PhaseTracer
from repro.txn.drivers import run_loop
from repro.txn.engine import single_host_engine
from repro.txn.tpcc import TPCCScale, init_state


def _tree_eq(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# Lattice laws. The property tests use hypothesis when available (same idiom
# as test_lattice.py); the deterministic law checks below always run, so the
# obs plane's core claims hold even in a hypothesis-less environment.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal containers
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:

    def _counters(num_replicas=3, value_shape=(2,)):
        n = num_replicas * int(np.prod(value_shape))
        return st.lists(st.integers(0, 50), min_size=n, max_size=n).map(
            lambda xs: lat.CounterLattice(jnp.asarray(
                np.array(xs, np.int32).reshape(num_replicas, *value_shape))))

    @settings(max_examples=50, deadline=None)
    @given(_counters(), _counters(), _counters())
    def test_counter_lattice_laws(a, b, c):
        j = lat.CounterLattice.join
        assert _tree_eq(j(a, b), j(b, a))
        assert _tree_eq(j(a, j(b, c)), j(j(a, b), c))
        assert _tree_eq(j(a, a), a)
        bottom = lat.CounterLattice.make(3, (2,))
        assert _tree_eq(j(a, bottom), a)  # identity

    def _hists(num_replicas=2, n_bins=8):
        n = num_replicas * n_bins
        return st.lists(st.integers(0, 50), min_size=n, max_size=n).map(
            lambda xs: lat.HistogramLattice.make(num_replicas, n_bins)
            ._replace(counts=jnp.asarray(
                np.array(xs, np.int32).reshape(num_replicas, n_bins))))

    @settings(max_examples=50, deadline=None)
    @given(_hists(), _hists(), _hists())
    def test_histogram_lattice_laws(a, b, c):
        j = lat.HistogramLattice.join
        assert _tree_eq(j(a, b), j(b, a))
        assert _tree_eq(j(a, j(b, c)), j(j(a, b), c))
        assert _tree_eq(j(a, a), a)
        bottom = lat.HistogramLattice.make(2, 8)
        assert _tree_eq(j(a, bottom), a)  # identity

    _obs_values = st.lists(
        st.floats(0, 1e4, allow_nan=False, allow_subnormal=False, width=32),
        min_size=1, max_size=12)

    @settings(max_examples=50, deadline=None)
    @given(_obs_values, _obs_values)
    def test_histogram_of_union_property(xs, ys):
        _check_histogram_of_union(xs, ys)


def _check_histogram_of_union(xs, ys):
    """join(hist(A), hist(B)) == hist(A ∪ B) when A and B were observed on
    disjoint replica lanes — the law the HistogramLattice docstring promises,
    and the reason merging snapshots across replicas never double-counts."""
    h0 = lat.HistogramLattice.make(2, 8)
    a = h0.observe(0, jnp.asarray(xs))
    b = h0.observe(1, jnp.asarray(ys))
    union = h0.observe(0, jnp.asarray(xs)).observe(1, jnp.asarray(ys))
    merged = lat.HistogramLattice.join(a, b)
    assert _tree_eq(merged, union)
    # and the merged value() is the histogram of all observations
    assert int(merged.value().sum()) == len(xs) + len(ys)


def test_histogram_of_union_examples():
    _check_histogram_of_union([1.0], [1.0])            # same bin, both lanes
    _check_histogram_of_union([0.0, 3.0, 7.5], [2.0])  # bin boundaries
    _check_histogram_of_union([1e4] * 5, [0.5, 300.0])  # open top bin


def test_counter_value_reflects_all_replicas():
    c0 = lat.CounterLattice.make(2, (4,))
    a = c0.bump(0, jnp.asarray([1, 1, 3]))      # replica 0: dup idx accumulate
    b = c0.bump(1, jnp.asarray([0]), amount=5)  # replica 1's local copy
    merged = lat.CounterLattice.join(a, b)
    assert merged.value().tolist() == [5, 2, 0, 1]


def test_registered_joins_pass_lattice_laws():
    counters = [lat.CounterLattice.make(2).bump(0, amount=k) for k in (1, 5, 2)]
    lat.check_lattice_laws(lat.CounterLattice.join, counters, eq=_tree_eq)
    hists = [lat.HistogramLattice.make(2, 8).observe(0, jnp.asarray([v]))
             for v in (1.0, 7.0, 300.0)]
    lat.check_lattice_laws(lat.HistogramLattice.join, hists, eq=_tree_eq)


def test_obs_metrics_pytree_join_is_lattice():
    def sample(seed):
        rng = np.random.default_rng(seed)
        m = obsm.make_obs_metrics(2, n_items=8)
        return obsm.ObsMetrics(
            latency=m.latency._replace(counts=jnp.asarray(
                rng.integers(0, 9, m.latency.counts.shape, dtype=np.int32))),
            aborts=lat.CounterLattice(jnp.asarray(
                rng.integers(0, 9, (2,), dtype=np.int32))),
            cold_rejects=lat.CounterLattice(jnp.asarray(
                rng.integers(0, 9, (2,), dtype=np.int32))),
            item_access=lat.CounterLattice(jnp.asarray(
                rng.integers(0, 9, (2, 8), dtype=np.int32))))
    lat.check_lattice_laws(obsm.obs_metrics_join,
                           [sample(s) for s in range(3)], eq=_tree_eq)


# ---------------------------------------------------------------------------
# Recorders: the deferred per-chunk folds count exactly what ran
# ---------------------------------------------------------------------------


class _FakeNewOrders:
    """Just the four fields record_chunk reads, stacked [T, B, ...]."""

    def __init__(self, i_id, n_lines, supply_w, w):
        self.i_id, self.n_lines, self.supply_w, self.w = i_id, n_lines, supply_w, w


def _fake_chunk(T=3, B=4, L=5, n_items=32, seed=0):
    rng = np.random.default_rng(seed)
    i_id = jnp.asarray(rng.integers(0, n_items, (T, B, L), dtype=np.int32))
    n_lines = jnp.asarray(rng.integers(1, L + 1, (T, B), dtype=np.int32))
    w = jnp.zeros((T, B), jnp.int32)
    supply_w = jnp.asarray(rng.integers(0, 2, (T, B, L), dtype=np.int32))
    return _FakeNewOrders(i_id, n_lines, supply_w, w)


def test_record_chunk_totals_merge_regime():
    T, B, n_items = 3, 4, 32
    no = _fake_chunk(T, B, n_items=n_items)
    m = obsm.record_chunk(obsm.make_obs_metrics(1, n_items), no, ok=None)
    lat_counts = np.asarray(m.latency.counts)[0]
    # every New-Order commits in the merge regime: one observation per txn
    assert int(lat_counts[obsm.TXN_TYPES.index("neworder")].sum()) == T * B
    # other txn types untouched by record_chunk
    assert int(lat_counts.sum()) == T * B
    # attempted item demand counts every VALID line, committed or not
    assert int(m.item_access.value().sum()) == int(no.n_lines.sum())


def test_record_chunk_latency_proxy_bins():
    # all-local chunk: every txn's visibility proxy is 1 step -> bin 0
    no = _fake_chunk()
    no.supply_w = jnp.zeros_like(no.supply_w)  # every line home-local
    m = obsm.record_chunk(obsm.make_obs_metrics(1, 32), no, ok=None)
    row = np.asarray(m.latency.counts)[0, obsm.TXN_TYPES.index("neworder")]
    assert row[0] == no.n_lines.size and row[1:].sum() == 0
    # all-remote chunk: step t commits at the chunk drain, proxy 1 + T - t > 1
    no2 = _fake_chunk()
    no2.supply_w = jnp.ones_like(no2.supply_w)
    m2 = obsm.record_chunk(obsm.make_obs_metrics(1, 32), no2, ok=None)
    row2 = np.asarray(m2.latency.counts)[0, obsm.TXN_TYPES.index("neworder")]
    assert row2[0] == 0 and row2.sum() == no2.n_lines.size


def test_record_chunk_commit_mask_weights():
    T, B = 3, 4
    no = _fake_chunk(T, B)
    ok = jnp.asarray(np.random.default_rng(1).integers(0, 2, (T, B)),
                     jnp.bool_)
    m = obsm.record_chunk(obsm.make_obs_metrics(1, 32), no, ok=ok)
    lat_counts = np.asarray(m.latency.counts)[0]
    # the latency histogram is committed-weighted...
    assert int(lat_counts.sum()) == int(ok.sum())
    # ...but item demand still counts aborted attempts (contention signal)
    assert int(m.item_access.value().sum()) == int(no.n_lines.sum())


def test_fold_counters_lands_in_bin_zero():
    m = obsm.make_obs_metrics(1, 8)
    one = lambda v: jnp.asarray([v], jnp.int32)
    m = obsm.fold_counters(m, one(5), one(3), one(2), one(1), one(7))
    lat_counts = np.asarray(m.latency.counts)[0]
    for name, want in (("payment", 5), ("order_status", 3),
                       ("stock_level", 2), ("delivery", 1)):
        row = lat_counts[obsm.TXN_TYPES.index(name)]
        assert row[0] == want and row.sum() == want  # local => proxy bin 0
    assert np.asarray(m.aborts.slots).tolist() == [7]


def test_histogram_quantile_upper_edge():
    h = lat.HistogramLattice.make(1, 8)  # interior edges [2, 4, ..., 128]
    counts = np.zeros(8, np.int64)
    counts[0], counts[3] = 10, 1
    assert obsm.histogram_quantile(h.edges, counts, 0.50) == 2.0
    assert obsm.histogram_quantile(h.edges, counts, 0.99) == 16.0
    assert obsm.histogram_quantile(h.edges, np.zeros(8), 0.5) == 0.0


# ---------------------------------------------------------------------------
# Engine level: metrics are write-only (bit-exactness) + totals cross-check
# ---------------------------------------------------------------------------

_RUN_KW = dict(batch_per_shard=8, n_batches=12, merge_every=4,
               remote_frac=0.2, payments=True, reads=True, deliveries=True,
               seed=3)


def _run_pair(stock_invariant):
    kw = {} if stock_invariant is None else dict(
        stock_invariant=stock_invariant)
    eng = single_host_engine(TPCCScale(n_warehouses=4), **kw)

    def fresh():  # per-run state: the executor donates its input buffers
        base = init_state(eng.scale)
        if stock_invariant == "strict":
            base = base._replace(s_quantity=base.s_quantity * 20)
        return eng.shard_state(base)

    s_off, _, st_off = run_loop(eng, fresh(), **_RUN_KW)
    obs = ObsSession(metrics=True, trace=True)
    s_on, _, st_on = run_loop(eng, fresh(), obs=obs, **_RUN_KW)
    return eng, (s_off, st_off), (s_on, st_on), obs


@pytest.mark.slow
def test_metrics_on_is_bit_exact_merge_regime():
    _, (s_off, st_off), (s_on, st_on), obs = _run_pair(None)
    assert _tree_eq(s_on, s_off)
    assert st_on.committed == st_off.committed
    snap = obs.snapshot()
    # histogram totals are the run's committed counts, per transaction type
    assert snap["latency"]["neworder"]["count"] == st_on.neworders
    assert snap["latency"]["payment"]["count"] == st_on.payments
    assert snap["latency"]["order_status"]["count"] == st_on.order_statuses
    assert snap["latency"]["stock_level"]["count"] == st_on.stock_levels
    assert snap["latency"]["delivery"]["count"] == st_on.deliveries
    assert sum(snap["counters"]["aborts_per_replica"]) == 0
    assert snap["item_access"]["total_line_demand"] > 0
    assert snap["spans"]["phases"]  # the tracer saw the loop's phases


@pytest.mark.slow
def test_metrics_on_is_bit_exact_escrow_regime():
    _, (s_off, st_off), (s_on, st_on), obs = _run_pair("strict")
    assert _tree_eq(s_on, s_off)
    assert (st_on.committed, st_on.aborts, st_on.cold_rejects) == \
           (st_off.committed, st_off.aborts, st_off.cold_rejects)
    snap = obs.snapshot()
    # committed-weighted histogram == committed New-Orders; the per-replica
    # abort/cold-reject counters sum to the stats the drain reported
    assert snap["latency"]["neworder"]["count"] == st_on.neworders
    assert sum(snap["counters"]["aborts_per_replica"]) == st_on.aborts
    assert sum(snap["counters"]["cold_rejects_per_replica"]) == \
        st_on.cold_rejects


# ---------------------------------------------------------------------------
# Coordination ledger: the zero hot budget
# ---------------------------------------------------------------------------

_CLEAN_HLO = "  %add.1 = f32[8]{0} add(%a.0, %b.0)\n"
_DIRTY_HLO = ("  %ar.1 = f32[128]{0} all-reduce(%x.0), "
              "replica_groups={{0,1}}\n")


def test_ledger_hot_budget():
    led = CoordinationLedger(context="unit", txns_per_chunk=10)
    led.add("hot scan", _CLEAN_HLO, hot=True)
    led.add("drain", _DIRTY_HLO, hot=False, calls_per_chunk=0.5)
    led.assert_budget()  # cold collectives are accounting, not violations
    assert led.hot_collectives() == 0
    assert led.bytes_per_chunk() == pytest.approx(512 * 0.5)
    assert led.bytes_per_txn() == pytest.approx(25.6)
    snap = led.snapshot()
    assert snap["hot_collectives"] == 0
    assert [e["phase"] for e in snap["phases"]] == ["hot scan", "drain"]

    led.add("leaky scan", _DIRTY_HLO, hot=True)
    with pytest.raises(AssertionError, match="leaky scan"):
        led.assert_budget()


@pytest.mark.slow
def test_build_ledger_hot_phases_are_collective_free():
    eng = single_host_engine(TPCCScale(n_warehouses=4),
                             stock_invariant="strict")
    led = build_ledger(eng, chunk_len=4, batch_per_shard=8, metrics=True)
    snap = led.snapshot()
    assert snap["hot_collectives"] == 0
    phases = {e["phase"]: e for e in snap["phases"]}
    # the obs plane's own programs are in their own ledger, hot-budgeted
    assert phases["metrics record"]["hot"]
    assert phases["metrics record"]["collectives"] == {}
    assert phases["metrics counter fold"]["collectives"] == {}


# ---------------------------------------------------------------------------
# Phase tracer
# ---------------------------------------------------------------------------


def test_tracer_span_accounting():
    tr = PhaseTracer(enabled=True)
    for _ in range(3):
        with tr.span("megastep"):
            pass
    with tr.span("drain"):
        pass
    snap = tr.snapshot()
    assert snap["phases"]["megastep"]["count"] == 3
    assert snap["phases"]["drain"]["count"] == 1
    shares = [p["share"] for p in snap["phases"].values()]
    assert sum(shares) == pytest.approx(1.0)


def test_tracer_disabled_is_inert():
    tr = PhaseTracer(enabled=False)
    with tr.span("megastep"):
        pass
    assert tr.snapshot()["phases"] == {}
