"""Liveness-aware share reclamation property tests.

Protocol level: a host-side model of escrow refresh with a liveness mask —
dead replicas' slots refresh to ZERO and their headroom partitions among
the survivors (``HotSetEscrow.make(..., alive=...)`` does the share math,
so the code under test computes every partition).  For ARBITRARY
interleavings of spends, drains, kills, recoveries, hot-set
promote/demote, and reclaim-refreshes:

* no cell's stock ever goes negative and total applied spend never exceeds
  the initial inventory (reclamation never manufactures admission
  capacity);
* a recovered replica adopting the current share table via the
  conservative join (min shares / max spent) never sees more headroom than
  the table grants it;
* shares partition their budgets EXACTLY through every promote / demote /
  reclaim combination (conservation).

The control: a NAIVE reclaim that folds dead headroom into survivors while
keeping the dead row (what a max-join of old and new share tables would
do) lets a resurrected replica spend its stale share on top of the
reclaimed copy — provably overselling.  Zeroing the dead slot is the
load-bearing half of reclamation, not an optimization.

Deterministic seeded sweep always runs; hypothesis search runs where
hypothesis is installed.
"""

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: deterministic sweep only
    HAVE_HYPOTHESIS = False

from repro.core.lattice import HotSetEscrow

R = 4          # replicas
CELLS = 6      # keyspace (hot set is a subset chosen at refresh time)


def _make_shares(keys: np.ndarray, budgets: np.ndarray,
                 alive: np.ndarray) -> np.ndarray:
    """The real share math: HotSetEscrow.make with the liveness mask."""
    esc = HotSetEscrow.make(R, keys.astype(np.int32),
                            budgets.astype(np.int32),
                            alive=alive.astype(np.int32))
    return np.asarray(esc.shares, np.int64)


class _ReclaimModel:
    """Escrow refresh/kill/recover replay over CELLS independent cells.

    ``naive_reclaim=True`` is the oversell control: refresh computes the
    survivor partition over the FULL budget but keeps dead rows at their
    stale values (a max-join of the old and new share tables), so a
    resurrected replica's stale share comes on top of the reclaimed copy.
    """

    def __init__(self, seed: int, naive_reclaim: bool = False):
        rng = np.random.default_rng(seed)
        self.q0 = rng.integers(5, 41, CELLS).astype(np.int64)
        self.stock = self.q0.copy()          # authoritative (owner) stock
        self.applied = np.zeros(CELLS, np.int64)
        self.alive = np.ones(R, bool)
        self.naive = naive_reclaim
        self.oversold = False
        self.hot = np.arange(CELLS)          # current hot set (cell ids)
        self.shares = _make_shares(self.hot, self.stock[self.hot],
                                   np.ones(R))
        self.spent = np.zeros_like(self.shares)
        # admitted-but-unshipped spends per replica: (cell, qty)
        self.outbox = [[] for _ in range(R)]
        # dead replicas' last table rows, snapshotted at refresh time
        self._stale = {}

    # -- ops -----------------------------------------------------------------

    def spend(self, r: int, cell: int, amt: int) -> None:
        if not self.alive[r]:
            return
        pos = np.where(self.hot == cell % CELLS)[0]
        if pos.size == 0:
            return                            # cell not hot this epoch
        k = int(pos[0])
        take = min(amt, int(self.shares[r, k] - self.spent[r, k]))
        if take <= 0:
            return
        self.spent[r, k] += take
        self.outbox[r].append((int(self.hot[k]), take))

    def drain(self) -> None:
        """Owners apply every live replica's shipped spends (hot entries
        apply unconditionally — the shares are the admission)."""
        for r in range(R):
            if not self.alive[r]:
                continue
            for cell, qty in self.outbox[r]:
                self.stock[cell] -= qty
                self.applied[cell] += qty
            self.outbox[r] = []
        if np.any(self.stock < 0):
            self.oversold = True

    def kill(self, r: int) -> None:
        """Crash: the replica's unshipped spends are lost with it (spent
        share wasted — the safe direction)."""
        self.alive[r] = False
        self.outbox[r] = []

    def recover(self, r: int) -> None:
        """Rejoin via the conservative join of the replica's stale view
        with the current table: min shares / max spent — never more
        headroom than the current table grants.  (If the hot set churned
        while the replica was dead, its stale view is not joinable
        cellwise; it adopts the current — possibly zeroed — row, the
        strictly conservative fallback.)  The naive control skips the
        join: the table row it resurrected with (kept stale by the naive
        refresh) is spendable as-is."""
        self.alive[r] = True
        if self.naive:
            return
        stale = self._stale.get(r)
        if stale is None or stale[0].shape[0] != self.shares.shape[1]:
            return
        joined_shares = np.minimum(stale[0], self.shares[r])
        joined_spent = np.maximum(stale[1], self.spent[r])
        assert np.all(joined_shares - joined_spent
                      <= self.shares[r] - self.spent[r]), \
            "conservative join manufactured headroom"
        self.shares[r] = joined_shares
        self.spent[r] = joined_spent

    def refresh(self, promote=None, demote=None) -> None:
        """Drain-quiescent share refresh with reclamation; optionally
        re-select the hot set (promote/demote) in the same epoch."""
        self.drain()
        self._stale = {r: (self.shares[r].copy(), self.spent[r].copy())
                       for r in range(R) if not self.alive[r]}
        hot = list(self.hot)
        if demote is not None and len(hot) > 1:
            hot.pop(demote % len(hot))
        if promote is not None and (promote % CELLS) not in hot:
            hot = sorted(hot + [promote % CELLS])
        self.hot = np.asarray(sorted(hot))
        budgets = self.stock[self.hot]
        alive = self.alive.astype(np.int64)
        new = _make_shares(self.hot, budgets, alive)
        # conservation: live shares partition the budgets exactly, dead
        # rows are zero (the min-join-safe half of reclamation); with NO
        # survivors nothing is allocated at all — capacity is stranded,
        # never manufactured
        if self.alive.any():
            assert np.array_equal(new.sum(0), budgets)
        assert np.all(new[~self.alive] == 0)
        if self.naive:
            # keep stale dead rows on top of the reclaimed partition
            for r in range(R):
                if not self.alive[r]:
                    old = self._stale[r][0]
                    if old.shape[0] == new.shape[1]:
                        new[r] = old
        self.shares = new
        self.spent = np.zeros_like(new)

    def finish(self) -> None:
        self.drain()
        assert not self.oversold, "stock went negative"
        assert np.all(self.applied <= self.q0), \
            "total applied spend exceeds initial inventory"
        assert np.array_equal(self.stock, self.q0 - self.applied)


def _random_ops(rng: np.random.Generator, n: int) -> list:
    ops = []
    for _ in range(n):
        k = rng.random()
        if k < 0.45:
            ops.append(("spend", int(rng.integers(R)),
                        int(rng.integers(CELLS)), int(rng.integers(1, 21))))
        elif k < 0.60:
            ops.append(("drain",))
        elif k < 0.70:
            ops.append(("kill", int(rng.integers(R))))
        elif k < 0.80:
            ops.append(("recover", int(rng.integers(R))))
        elif k < 0.88:
            ops.append(("refresh",))
        elif k < 0.94:
            ops.append(("refresh_promote", int(rng.integers(CELLS))))
        else:
            ops.append(("refresh_demote", int(rng.integers(CELLS))))
    return ops


def _run_ops(model: _ReclaimModel, ops: list) -> None:
    for op in ops:
        kind = op[0]
        if kind == "spend":
            model.spend(op[1], op[2], op[3])
        elif kind == "drain":
            model.drain()
        elif kind == "kill":
            model.kill(op[1])
        elif kind == "recover":
            model.recover(op[1])
        elif kind == "refresh_promote":
            model.refresh(promote=op[1])
        elif kind == "refresh_demote":
            model.refresh(demote=op[1])
        else:
            model.refresh()
    model.finish()


def test_reclaim_interleavings_never_oversell_seeded():
    """Deterministic sweep: 80 seeded schedules over spends, drains,
    kills, recoveries, and reclaim-refreshes with hot-set churn — stock
    never negative, conservation exact, joins conservative."""
    for seed in range(80):
        rng = np.random.default_rng(4000 + seed)
        _run_ops(_ReclaimModel(seed), _random_ops(rng,
                                                  int(rng.integers(5, 61))))


def test_naive_reclaim_into_max_join_oversells():
    """The control: reclaiming a dead replica's headroom WITHOUT zeroing
    its slot (what a max-join of share tables would keep) lets the
    resurrected replica spend its stale share on top of the reclaimed
    copy — the budget is allocated twice and stock goes negative."""
    m = _ReclaimModel(0, naive_reclaim=True)
    m.stock[:] = 10
    m.q0[:] = 10
    m.refresh()                 # shares partition 10 over 4 live replicas
    m.kill(1)
    m.refresh()                 # survivors get ALL of 10; row 1 kept stale
    assert m.shares[~m.alive].sum() > 0, "control must keep the stale row"
    for r in (0, 2, 3):
        m.spend(r, 0, 10)       # survivors exhaust the reclaimed budget
    m.drain()
    m.recover(1)                # resurrect WITHOUT the conservative join
    m.spend(1, 0, 10)           # stale share admits on top
    m.drain()
    assert m.oversold, "naive reclaim must oversell"

    # the same schedule under the real scheme stays safe
    m2 = _ReclaimModel(0)
    m2.stock[:] = 10
    m2.q0[:] = 10
    m2.refresh()
    m2.kill(1)
    m2.refresh()
    assert np.all(m2.shares[1] == 0)
    for r in (0, 2, 3):
        m2.spend(r, 0, 10)
    m2.drain()
    m2.recover(1)               # min-join zeroes the stale share
    m2.spend(1, 0, 10)
    m2.finish()                 # no oversell, conservation exact


def test_reclaimed_partition_is_exact_and_minjoin_safe():
    """Direct laws of the alive-masked partition (the code under test is
    HotSetEscrow.make): live rows partition the budget exactly, dead rows
    are zero, and an all-live partition is identical to the unmasked one."""
    rng = np.random.default_rng(7)
    keys = np.arange(CELLS)
    for _ in range(50):
        budgets = rng.integers(0, 100, CELLS)
        alive = (rng.random(R) < 0.7).astype(np.int64)
        shares = _make_shares(keys, budgets, alive)
        assert np.array_equal(shares.sum(0), budgets)
        assert np.all(shares[alive == 0] == 0)
    budgets = rng.integers(0, 100, CELLS)
    masked = _make_shares(keys, budgets, np.ones(R))
    unmasked = np.asarray(HotSetEscrow.make(
        R, keys.astype(np.int32), budgets.astype(np.int32)).shares, np.int64)
    assert np.array_equal(masked, unmasked)


if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.one_of(
            st.tuples(st.just("spend"), st.integers(0, R - 1),
                      st.integers(0, CELLS - 1), st.integers(1, 20)),
            st.tuples(st.just("drain")),
            st.tuples(st.just("kill"), st.integers(0, R - 1)),
            st.tuples(st.just("recover"), st.integers(0, R - 1)),
            st.tuples(st.just("refresh")),
            st.tuples(st.just("refresh_promote"), st.integers(0, CELLS - 1)),
            st.tuples(st.just("refresh_demote"), st.integers(0, CELLS - 1))),
        min_size=5, max_size=60)

    @settings(max_examples=80, deadline=None)
    @given(seed=st.integers(0, 10_000), ops=_ops)
    def test_reclaim_interleavings_never_oversell(seed, ops):
        """Hypothesis search over kill/recover/reclaim interleavings."""
        _run_ops(_ReclaimModel(seed), list(ops))
