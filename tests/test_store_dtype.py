"""Version-stamp dtype is resolved at Table construction, not import time
(enabling x64 after import must widen stamps for new tables). Runs without
hypothesis — the property suite in test_store.py needs it."""

import subprocess
import sys

import jax.numpy as jnp

from repro.txn import store
from repro.txn.store import Table, version_dtype

_SUBPROC = r"""
import jax
from repro.txn import store
t32 = store.Table.make(4, {"x": "float32"})
jax.config.update("jax_enable_x64", True)   # enabled AFTER import
t64 = store.Table.make(4, {"x": "float32"})
assert t32.version.dtype.name == "int32", t32.version.dtype
assert t64.version.dtype.name == "int64", t64.version.dtype
v = store.namespaced_version(jax.numpy.asarray(7), 1, 4)
assert v.dtype.name == "int64", v.dtype
print("DTYPE-OK")
"""


def test_version_dtype_tracks_x64_flag_after_import():
    import os
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DTYPE-OK" in out.stdout


def test_module_constant_backcompat():
    assert store.VERSION_DTYPE == version_dtype()


def test_table_ops_use_constructed_dtype():
    t = Table.make(4, {"x": jnp.float32})
    t = t.insert(jnp.asarray([0]), {"x": jnp.asarray([1.5])},
                 jnp.asarray([3]))
    assert t.version.dtype == version_dtype()
    t = t.update(jnp.asarray([0]), {"x": jnp.asarray([2.5])},
                 jnp.asarray([5]))
    assert int(t.version[0]) == 5 and t.version.dtype == version_dtype()
