"""Per-architecture smoke tests: reduced config, one forward + one gradient
step on CPU, shape + finiteness assertions; decode smoke where applicable."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import kv_cache, rwkv6 as rwkv6_mod, hymba as hymba_mod
from repro.models import vlm as vlm_mod, whisper as whisper_mod
from repro.models.sharding import Rules

RULES = Rules.disabled()
B, S = 2, 16


def _reduced(arch):
    return registry.get_config(arch).reduced()


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = registry.get_config(arch)
    expected = {
        "qwen3-moe-30b-a3b": dict(n_layers=48, d_model=2048, n_heads=32,
                                  n_kv_heads=4, vocab=151_936, n_experts=128,
                                  top_k=8),
        "olmoe-1b-7b": dict(n_layers=16, d_model=2048, n_heads=16,
                            n_kv_heads=16, vocab=50_304, n_experts=64, top_k=8),
        "qwen1.5-32b": dict(n_layers=64, d_model=5120, n_heads=40,
                            n_kv_heads=40, d_ff=27_392, vocab=152_064,
                            qkv_bias=True),
        "smollm-360m": dict(n_layers=32, d_model=960, n_heads=15,
                            n_kv_heads=5, d_ff=2560, vocab=49_152),
        "tinyllama-1.1b": dict(n_layers=22, d_model=2048, n_heads=32,
                               n_kv_heads=4, d_ff=5632, vocab=32_000),
        "minitron-8b": dict(n_layers=32, d_model=4096, n_heads=32,
                            n_kv_heads=8, d_ff=16_384, vocab=256_000),
        "rwkv6-3b": dict(n_layers=32, d_model=2560, d_ff=8960, vocab=65_536),
        "hymba-1.5b": dict(n_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, ssm_state=16),
        "llama-3.2-vision-11b": dict(n_layers=40, d_model=4096, n_heads=32,
                                     n_kv_heads=8, d_ff=14_336, vocab=128_256),
        "whisper-tiny": dict(n_layers=4, enc_layers=4, d_model=384, n_heads=6,
                             n_kv_heads=6, d_ff=1536, vocab=51_865),
    }[arch]
    for field, val in expected.items():
        assert getattr(cfg, field) == val, (arch, field, getattr(cfg, field))


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_forward_and_grad_step(arch):
    cfg = _reduced(arch)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    batch = registry.make_train_batch(jax.random.PRNGKey(1), cfg, B, S)
    loss_fn = registry.make_loss_fn(cfg, RULES, remat=False)

    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    assert jnp.isfinite(loss), (arch, float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves, arch
    assert all(jnp.isfinite(g).all() for g in leaves), arch

    # one SGD step changes the loss (learning signal flows)
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    loss2 = loss_fn(params2, batch)
    assert jnp.isfinite(loss2)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_decode_step(arch):
    cfg = _reduced(arch)
    shape = dataclasses.replace(
        registry.SHAPES["decode_32k"], seq_len=S, global_batch=B)
    ok, why = registry.cell_supported(cfg, shape)
    if not ok:
        pytest.skip(why)

    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    decode = registry.make_decode_fn(cfg, RULES)

    # build a concrete cache matching the abstract specs
    if cfg.family == "ssm":
        cache = rwkv6_mod.stacked_state(cfg, B)
    elif cfg.family == "hybrid":
        cache = hymba_mod.make_cache(cfg, B)
    elif cfg.family == "vlm":
        cache = vlm_mod.make_cache(cfg, B, S)
        img = jax.random.normal(jax.random.PRNGKey(3),
                                (B, cfg.image_tokens, cfg.d_model),
                                jnp.dtype(cfg.dtype))
        ck, cv = vlm_mod.build_cross_kv(params, img, cfg)
        cache = cache._replace(ck=ck.astype(cache.ck.dtype),
                               cv=cv.astype(cache.cv.dtype))
    elif cfg.family == "audio":
        cache = whisper_mod.make_cache(cfg, B, S)
        frames = jax.random.normal(jax.random.PRNGKey(3),
                                   (B, cfg.n_frames, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        enc = whisper_mod.encode(params, frames, cfg, RULES, remat=False)
        ck, cv = whisper_mod.build_cross_kv(params, enc, cfg)
        cache = cache._replace(ck=ck.astype(cache.ck.dtype),
                               cv=cv.astype(cache.cv.dtype))
    else:
        cache = kv_cache.make_cache(cfg, cfg.n_layers, B, S)

    token = jnp.zeros((B,), jnp.int32)
    for _ in range(3):
        lg, cache = decode(params, cache, token)
        assert lg.shape == (B, cfg.padded_vocab())
        # logical vocab entries finite; padded tail masked out of argmax
        assert jnp.isfinite(lg[:, :cfg.vocab]).all(), arch
        token = jnp.argmax(lg, -1).astype(jnp.int32)
        assert int(token.max()) < cfg.vocab


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_input_specs_are_abstract(arch):
    cfg = registry.get_config(arch)
    for shape in registry.SHAPES.values():
        ok, _ = registry.cell_supported(cfg, shape)
        if not ok:
            continue
        if shape.kind == "train":
            specs = registry.train_input_specs(cfg, shape)
            assert all(isinstance(v, jax.ShapeDtypeStruct) for v in specs.values())
        elif shape.kind in ("decode", "long_decode"):
            cache, token = registry.decode_input_specs(cfg, shape)
            leaves = [l for l in jax.tree_util.tree_leaves(cache)]
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_param_counts_in_expected_range():
    """Sanity: implementations roughly land at their nameplate sizes."""
    expect = {
        "qwen3-moe-30b-a3b": (25e9, 36e9),
        "olmoe-1b-7b": (5.5e9, 8.5e9),
        "qwen1.5-32b": (28e9, 37e9),
        "smollm-360m": (0.30e9, 0.45e9),
        "tinyllama-1.1b": (0.9e9, 1.3e9),
        "minitron-8b": (7e9, 10.5e9),
        "rwkv6-3b": (2.2e9, 3.6e9),
        "hymba-1.5b": (1.0e9, 1.9e9),
        "llama-3.2-vision-11b": (7.5e9, 12e9),
        "whisper-tiny": (0.025e9, 0.06e9),
    }
    for arch, (lo, hi) in expect.items():
        n = registry.exact_param_count(registry.get_config(arch))
        assert lo <= n <= hi, (arch, n / 1e9)
    # MoE active params ~3B / ~1B
    a = registry.exact_active_param_count(registry.get_config("qwen3-moe-30b-a3b"))
    assert 2e9 <= a <= 4.5e9, a / 1e9
    a = registry.exact_active_param_count(registry.get_config("olmoe-1b-7b"))
    assert 0.8e9 <= a <= 1.8e9, a / 1e9
