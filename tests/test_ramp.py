"""RAMP atomic-visibility subsystem (txn/ramp.py + kernels/ramp_read.py):

* randomized interleavings: readers NEVER observe a fractured New-Order
  write set (order visible => all order-lines + metadata visible), while a
  control reader with metadata disabled does observe fractures;
* the compiled read path (Order-Status / Stock-Level over sharded state)
  contains zero collective ops, verified structurally from HLO;
* read transactions agree with a pure-numpy oracle on converged state;
* the fused Pallas kernel matches its jnp oracle bit-exactly (interpret);
* the 2PC-synchronized read baseline must carry collectives.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.txn import ramp, tpcc
from repro.txn.engine import run_mixed_loop, single_host_engine
from repro.txn.tpcc import TPCCScale, check_consistency, init_state
from repro.txn.twopc import TwoPCEngine

SCALE = TPCCScale(n_warehouses=2, districts=2, customers=8, n_items=32,
                  order_capacity=64, max_lines=15)


@pytest.fixture(scope="module")
def engine():
    return single_host_engine(SCALE)


def _apply_batch(state, rng, ts0, batch=12):
    b = tpcc.generate_neworder(rng, SCALE, batch, remote_frac=0.2, ts0=ts0)
    state, _, _ = tpcc.apply_neworder(state, b, SCALE)
    return state, b


# ---------------------------------------------------------------------------
# the atomic-visibility property
# ---------------------------------------------------------------------------


def test_randomized_interleavings_never_fracture():
    """For arbitrary write/conceal/read/publish interleavings, the RAMP
    reader returns complete write sets; the metadata-less control reader
    observes fractures in the same states."""
    rng = np.random.default_rng(0)
    state = init_state(SCALE)
    ts0 = 0
    control_fractures = 0
    checked_reads = 0
    for trial in range(12):
        state, b = _apply_batch(state, rng, ts0)
        ts0 += 12
        # conceal a random subset of committed-layer visibility bits —
        # commit propagation caught mid-flight at a random interleaving
        drop = jnp.asarray(rng.random(state.ol_vis.shape) < rng.uniform(0.2, 0.9))
        staged = ramp.conceal_lines(state, drop)

        queries = tpcc.OrderStatusBatch(w=b.w, d=b.d, c=b.c)
        r = ramp.apply_order_status(staged, queries)
        assert int(r.fractures_observed()) == 0
        # complete sets: every found order returns exactly its sibling count
        assert bool((~r.found | (r.lines_read == r.n_lines)).all())
        checked_reads += int(r.found.sum())

        ctl = ramp.apply_order_status(staged, queries, use_metadata=False)
        control_fractures += int(ctl.fractures_observed())

        sl = tpcc.generate_stock_level(rng, SCALE, 8)
        sr = ramp.apply_stock_level(staged, sl, SCALE)
        assert int((sr.fractured - sr.repaired).sum()) == 0
        ctl_sr = ramp.apply_stock_level(staged, sl, SCALE, use_metadata=False)
        control_fractures += int(ctl_sr.fractured.sum())

        # randomly publish (commit propagation completes) or keep staging
        if rng.random() < 0.5:
            state = ramp.publish_lines(staged)
    assert checked_reads > 0
    assert control_fractures > 0, \
        "control (metadata disabled) must observe fractures"


def test_repair_round_serves_exactly_the_concealed_lines():
    rng = np.random.default_rng(1)
    state, b = _apply_batch(init_state(SCALE), rng, 0)
    drop = jnp.asarray(rng.random(state.ol_vis.shape) < 0.5) & state.ol_vis
    staged = ramp.conceal_lines(state, drop)
    queries = tpcc.OrderStatusBatch(w=b.w, d=b.d, c=b.c)
    r = ramp.apply_order_status(staged, queries)
    # the lookback round served something, and after publish it goes quiet
    assert int(r.repaired.sum()) > 0
    r2 = ramp.apply_order_status(ramp.publish_lines(staged), queries)
    assert int(r2.repaired.sum()) == 0
    assert bool((r2.lines_read == r.lines_read).all())


def test_delivery_read_side_repairs_amounts():
    """Delivery must credit the COMPLETE line sum even mid-propagation —
    a fractured read here would corrupt criteria 10/12."""
    rng = np.random.default_rng(2)
    state, _ = _apply_batch(init_state(SCALE), rng, 0)
    concealed = ramp.conceal_lines(
        state, jnp.asarray(rng.random(state.ol_vis.shape) < 0.7))
    full = ramp.delivery_read(state)
    staged = ramp.delivery_read(concealed)
    assert bool(jnp.allclose(full.amount, staged.amount))
    assert int(staged.repaired.sum()) > 0
    # and apply_delivery's balance credit matches the repaired read
    d1 = tpcc.apply_delivery(state, jnp.asarray(1, jnp.int32),
                             jnp.asarray(0, jnp.int32))
    d2 = tpcc.apply_delivery(concealed, jnp.asarray(1, jnp.int32),
                             jnp.asarray(0, jnp.int32))
    assert bool(jnp.allclose(d1.c_balance, d2.c_balance))


# ---------------------------------------------------------------------------
# oracle agreement on converged state
# ---------------------------------------------------------------------------


def test_order_status_matches_numpy_oracle():
    rng = np.random.default_rng(3)
    state = init_state(SCALE)
    for i in range(4):
        state, b = _apply_batch(state, rng, i * 12)
    q = tpcc.generate_order_status(rng, SCALE, 16)
    r = ramp.apply_order_status(state, q)

    s = jax.device_get(state)
    for k in range(16):
        w, d, c = int(q.w[k]), int(q.d[k]), int(q.c[k])
        mask = s.o_valid[w, d] & (s.o_c_id[w, d] == c) & (s.o_ts[w, d] >= 0)
        assert bool(r.found[k]) == bool(mask.any())
        if not mask.any():
            continue
        slot = int(np.argmax(np.where(mask, s.o_ts[w, d], -1)))
        n = int(s.o_ol_cnt[w, d, slot])
        assert int(r.n_lines[k]) == n
        assert int(r.lines_read[k]) == n
        np.testing.assert_array_equal(
            np.asarray(r.i_id[k][:n]), s.ol_i_id[w, d, slot][:n])
        np.testing.assert_allclose(
            np.asarray(r.amount[k][:n]), s.ol_amount[w, d, slot][:n])


def test_stock_level_matches_numpy_oracle():
    rng = np.random.default_rng(4)
    state = init_state(SCALE)
    for i in range(6):
        state, _ = _apply_batch(state, rng, i * 12)
    q = tpcc.generate_stock_level(rng, SCALE, 16)
    r = ramp.apply_stock_level(state, q, SCALE)

    s = jax.device_get(state)
    OC = SCALE.order_capacity
    for k in range(16):
        w, d, thr = int(q.w[k]), int(q.d[k]), int(q.threshold[k])
        items = set()
        nxt = int(s.d_next_o_id[w, d])
        for oid in range(max(0, nxt - ramp.STOCK_LEVEL_ORDERS), nxt):
            slot = oid % OC
            n = int(s.o_ol_cnt[w, d, slot])
            items.update(int(x) for x in s.ol_i_id[w, d, slot][:n])
        want = sum(1 for i in items if int(s.s_quantity[w, i]) < thr)
        assert int(r.low_count[k]) == want


# ---------------------------------------------------------------------------
# structural coordination-freedom + engine integration
# ---------------------------------------------------------------------------


def test_read_path_zero_collectives(engine):
    desc = engine.prove_read_coordination_free(batch_per_shard=8)
    assert desc.count("NONE") == 2


def test_2pc_read_baseline_has_collectives(engine):
    two = TwoPCEngine(SCALE, engine.mesh, engine.axis_names)
    stats = two.read_path_collectives(8)
    assert stats.total_ops > 0, "2PC-synchronized reads must coordinate"


def test_mixed_loop_reads_consistent(engine):
    state = engine.shard_state(init_state(SCALE))
    state, stats = run_mixed_loop(engine, state, batch_per_shard=8,
                                  n_batches=6, remote_frac=0.3,
                                  merge_every=2, seed=5)
    assert stats.fractures_observed == 0
    # every batch is timed now (warmup compiles on throwaway copies)
    assert stats.neworders == 8 * 6 and stats.order_statuses > 0
    assert all(check_consistency(state).values())


# ---------------------------------------------------------------------------
# fused Pallas kernel vs jnp oracle (interpret mode: bit-exact)
# ---------------------------------------------------------------------------

KERNEL_CASES = [
    # (R, L, block_rows)
    (8, 15, 8),
    (64, 15, 16),
    (128, 8, 128),
    (256, 15, 64),
]


@pytest.mark.parametrize("R,L,block", KERNEL_CASES)
def test_ramp_read_kernel_bitexact(R, L, block):
    rng = np.random.default_rng(R * 31 + L)
    req = jnp.asarray(rng.integers(0, 40, R).astype(np.int32))
    nl = jnp.asarray(rng.integers(0, L + 1, R).astype(np.int32))
    ts = jnp.asarray(rng.integers(-1, 40, (R, L)).astype(np.int32))
    vis = jnp.asarray(rng.random((R, L)) < 0.6)
    prep = vis | jnp.asarray(rng.random((R, L)) < 0.7)
    amt = jnp.asarray(rng.uniform(0, 100, (R, L)).astype(np.float32))
    iid = jnp.asarray(rng.integers(0, 999, (R, L)).astype(np.int32))

    got = ops.ramp_read_select(req, nl, ts, vis, prep, amt, iid,
                               block_rows=block)
    want = ref.ramp_read_ref(req, nl, ts, vis, prep, amt, iid)
    for g, w in zip(got, want):
        assert g.dtype == w.dtype and g.shape == w.shape
        assert bool((g == w).all()), "kernel diverged from oracle"


def test_ramp_read_kernel_repairs_like_read_lines():
    """Kernel semantics == ramp.read_lines on real state arrays."""
    rng = np.random.default_rng(9)
    state, b = _apply_batch(init_state(SCALE), rng, 0)
    staged = ramp.conceal_lines(
        state, jnp.asarray(rng.random(state.ol_vis.shape) < 0.5))
    wl, d = b.w, b.d
    cand = (staged.o_valid[wl, d] & (staged.o_ts[wl, d] >= 0)
            & (staged.o_c_id[wl, d] == b.c[:, None]))
    slot = jnp.argmax(jnp.where(cand, staged.o_ts[wl, d], -1), -1)
    lr = ramp.read_lines(staged, wl, d, slot)
    present, _, _, _, lines_read, repaired = ops.ramp_read_select(
        staged.o_ts[wl, d, slot], staged.o_ol_cnt[wl, d, slot],
        staged.ol_ts[wl, d, slot], staged.ol_vis[wl, d, slot],
        staged.ol_valid[wl, d, slot], staged.ol_amount[wl, d, slot],
        staged.ol_i_id[wl, d, slot])
    assert bool((present == lr.present).all())
    assert bool((repaired == lr.repaired.sum(-1)).all())
