import os
import sys

# make `benchmarks` importable when running `PYTHONPATH=src pytest tests/`
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
