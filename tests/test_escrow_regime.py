"""Escrow-regime property tests (paper §8, O'Neil's escrow method).

Lattice level: for ARBITRARY interleavings of per-replica ``try_spend``s,
gossip ``join``s, and global share ``refresh``es, the escrowed stock can
never go below zero and the total admitted spend can never exceed the
initial inventory — while a control protocol with naive local decrements
(each replica checks only its own view of stock) does violate both.

Engine level: random adversarial demand streams through the plan-selected
escrow regime keep strict ``s_quantity >= 0`` and pass the full consistency
audit (repro/txn/audit.py) on every run.

The simulation core is shared between a deterministic seeded sweep (always
runs) and a hypothesis-driven search (runs where hypothesis is installed —
CI installs it via the ``test`` extra).
"""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: deterministic sweep only
    HAVE_HYPOTHESIS = False

from repro.core.lattice import EscrowCounter
from repro.txn.audit import assert_audit
from repro.txn.engine import run_escrow_loop, single_host_engine
from repro.txn.tpcc import TPCCScale, init_state

R, W, I = 3, 2, 3  # replicas x warehouses x items (lattice-level model)


def _partition(stock: np.ndarray) -> np.ndarray:
    r = np.arange(R)[:, None, None]
    return (stock // R + (r < stock % R)).astype(np.int64)


def _join(a: EscrowCounter, b: EscrowCounter) -> EscrowCounter:
    return EscrowCounter(np.minimum(a.shares, b.shares),
                         np.maximum(a.spent, b.spent))


def _simulate_escrow(seed: int, ops: list) -> None:
    """Replay one interleaving; assert the invariants the paper's §8 escrow
    method guarantees: Σ admitted spend <= initial inventory per cell, and
    the replayed owner-side stock never dips below zero."""
    rng = np.random.default_rng(seed)
    stock0 = rng.integers(0, 60, (W, I)).astype(np.int64)
    stock = stock0.copy()           # owner-side stock, updated at refresh
    total_admitted = np.zeros((W, I), np.int64)

    shares = _partition(stock)
    views = [EscrowCounter(shares.copy(), np.zeros_like(shares))
             for _ in range(R)]

    def global_sync():
        """Merge every view, apply the admitted spends to stock, and hand
        out fresh shares — the amortized coordination point."""
        nonlocal stock, views
        m = views[0]
        for v in views[1:]:
            m = _join(m, v)
        spent_total = m.spent.sum(0)
        stock = stock - spent_total
        assert np.all(stock >= 0), "refresh drove stock negative"
        fresh_shares = _partition(stock)
        views = [EscrowCounter(fresh_shares.copy(),
                               np.zeros((R, W, I), np.int64))
                 for _ in range(R)]

    for op in ops:
        if op[0] == "spend":
            _, r, w, i, amt = op
            v = views[r]
            if v.spent[r, w, i] + amt <= v.shares[r, w, i]:  # try_spend
                v.spent[r, w, i] += amt
                total_admitted[w, i] += amt
        elif op[0] == "gossip":
            _, r1, r2 = op
            views[r1] = _join(views[r1], views[r2])
            # gossip must never manufacture admission capacity
            assert np.all(views[r1].spent[r1] <= views[r1].shares[r1])
        else:
            global_sync()

    global_sync()
    assert np.all(total_admitted <= stock0), \
        "escrow admitted more spend than the initial inventory"
    assert np.array_equal(stock, stock0 - total_admitted)


def _random_ops(rng: np.random.Generator, n: int) -> list:
    ops = []
    for _ in range(n):
        k = rng.random()
        if k < 0.75:
            ops.append(("spend", int(rng.integers(R)), int(rng.integers(W)),
                        int(rng.integers(I)), int(rng.integers(1, 41))))
        elif k < 0.9:
            ops.append(("gossip", int(rng.integers(R)),
                        int(rng.integers(R))))
        else:
            ops.append(("refresh",))
    return ops


def test_escrow_interleavings_never_oversell_seeded():
    """Deterministic sweep of the interleaving property (no hypothesis
    needed): 60 seeded random schedules, spend-heavy and refresh-light."""
    for seed in range(60):
        rng = np.random.default_rng(1000 + seed)
        _simulate_escrow(seed, _random_ops(rng, int(rng.integers(5, 61))))


if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.one_of(
            st.tuples(st.just("spend"), st.integers(0, R - 1),
                      st.integers(0, W - 1), st.integers(0, I - 1),
                      st.integers(1, 40)),
            st.tuples(st.just("gossip"), st.integers(0, R - 1),
                      st.integers(0, R - 1)),
            st.tuples(st.just("refresh"))),
        min_size=5, max_size=60)

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 10_000), ops=_ops)
    def test_escrow_interleavings_never_oversell(seed, ops):
        """Hypothesis search over spend/gossip/refresh interleavings."""
        _simulate_escrow(seed, ops)


def test_naive_local_decrements_do_oversell():
    """The control: replicas that check only their LOCAL view of stock
    (no shares) jointly cross the floor — the paper's two-withdrawals
    anomaly, and why GREATER_THAN x decrement lands in Table 2's
    non-confluent cell."""
    stock0 = np.full((W, I), 50, np.int64)
    local_spent = [np.zeros((W, I), np.int64) for _ in range(R)]
    # every replica greedily sells 40 units of cell (0, 0): each sees
    # 50 - 40 >= 0 locally and admits it
    for r in range(R):
        if stock0[0, 0] - local_spent[r][0, 0] - 40 >= 0:
            local_spent[r][0, 0] += 40
    total = sum(s[0, 0] for s in local_spent)
    assert total > stock0[0, 0]             # oversold: 120 > 50
    assert stock0[0, 0] - total < 0         # merged stock goes negative


SCALE = TPCCScale(n_warehouses=2, districts=2, customers=8, n_items=32,
                  order_capacity=256, max_lines=15)


@pytest.fixture(scope="module")
def escrow_engine():
    return single_host_engine(SCALE, stock_invariant="strict")


def _engine_stream_case(eng, seed, merge_every, refresh_every, remote_frac):
    state = eng.shard_state(init_state(SCALE, seed=seed % 5))
    q0 = state.s_quantity.copy()
    state, esc, stats = run_escrow_loop(
        eng, state, batch_per_shard=8, n_batches=6, remote_frac=remote_frac,
        merge_every=merge_every, refresh_every=refresh_every, seed=seed,
        mix=True, fused=True)
    assert stats.neworders + stats.aborts == 8 * 6
    assert int(jax.device_get(state.s_quantity).min()) >= 0
    assert_audit(state, escrow=esc, initial_stock=q0, strict_stock=True)


@pytest.mark.parametrize("seed,merge_every,refresh_every,remote_frac", [
    (0, 2, 1, 0.0), (7, 3, 2, 0.5), (23, 2, 2, 0.5), (99, 3, 1, 0.0),
])
def test_engine_escrow_streams_audit_clean(escrow_engine, seed, merge_every,
                                           refresh_every, remote_frac):
    """Adversarial demand streams (inventory is tiny relative to demand)
    through the plan-selected escrow regime: strict stock holds and the
    full audit — incl. Σ(shares - spent) == s_quantity conservation —
    passes for every seed/cadence."""
    _engine_stream_case(escrow_engine, seed, merge_every, refresh_every,
                        remote_frac)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10_000),
           merge_every=st.sampled_from([2, 3]),
           refresh_every=st.sampled_from([1, 2]),
           remote_frac=st.sampled_from([0.0, 0.5]))
    def test_engine_escrow_streams_audit_clean_hypothesis(
            escrow_engine, seed, merge_every, refresh_every, remote_frac):
        _engine_stream_case(escrow_engine, seed, merge_every, refresh_every,
                            remote_frac)
